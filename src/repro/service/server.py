"""Concurrent verification service: many clients, one verdict store.

``VersionChainSession`` answers one client's chain; this module multiplexes
*N* concurrent sessions over a shared ``VerdictCache``/``EVRegistry`` — the
GEqO observation that equivalence detection pays off at cloud scale only
when the verifier front-end is cheap and parallel, applied to Veer's
windowed search.  The design (see docs/ARCHITECTURE.md, concurrency model):

  * one **bounded job queue** (``queue_size``) gives backpressure: ``submit``
    blocks (or raises ``ServiceBusy``) when the service is saturated instead
    of buffering unboundedly;
  * a fixed **worker pool** drains the queue.  Jobs of the same client are
    serialized *in submission order* via per-session tickets — a chain
    session is stateful (pair k needs pair k-1's predecessor), so its jobs
    must never run concurrently or out of order — while jobs of different
    clients run in parallel;
  * all sessions share one thread-safe ``VerdictCache``: the first client to
    pay for a window verdict answers it for every other client (and for the
    next process, via ``save``'s atomic snapshot);
  * every verdict keeps its replayable ``Certificate`` — concurrency never
    downgrades auditable evidence to trust-me.

Execute-with-reuse sessions inherit their data plane from the shared
``VeerConfig`` (``plane="jax"`` runs every client's chains on the
vectorized plane; see docs/DATA_PLANE.md) — planes are byte-identical by
contract, so this changes throughput, never results.

Typical use::

    from repro.api import VeerConfig
    from repro.service import VerificationService

    with VerificationService(config=VeerConfig(), workers=4) as svc:
        for client, version in incoming:
            svc.submit(client, version)       # Future[PairReport | None]
        report = svc.drain()                  # wait; aggregate stats
        print(report.summary())

``submit_pair`` is the stateless one-shot sibling (no session, any worker):
it verifies a single ``(P, Q)`` pair on the shared cache and resolves to a
``repro.api.VerificationResult``.
"""

from __future__ import annotations

import queue
import threading
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.api.config import VeerConfig
from repro.api.facade import VerificationResult, verify
from repro.api.registry import EVRegistry
from repro.core.dag import DataflowDAG
from repro.core.edits import EditMapping
from repro.core.ev.cache import VerdictCache
from repro.service.chain import ChainReport, PairReport, VersionChainSession
from repro.service.pair_cache import PairVerdictCache
from repro.service.remote.adapters import TieredPairCache, TieredVerdictCache
from repro.service.remote.tier import make_tier


class ServiceClosed(RuntimeError):
    """Submit after ``close()`` (the worker pool is gone)."""


class ServiceBusy(RuntimeError):
    """The bounded queue is full and the caller declined to wait."""


@dataclass
class ServiceReport:
    """Aggregate over everything the service verified up to ``drain``."""

    sessions: Dict[str, ChainReport]
    pair_results: List[VerificationResult]
    errors: List[str]
    cache_stats: Dict[str, object] = field(default_factory=dict)
    pair_cache_stats: Dict[str, object] = field(default_factory=dict)

    @property
    def reused_pairs(self) -> int:
        """Pairs answered wholesale from the shared pair-verdict cache
        (chain-session pairs and one-shot ``submit_pair`` results alike)."""
        return sum(r.reused_pairs for r in self.sessions.values()) + sum(
            1 for p in self.pair_results if p.reused
        )

    @property
    def total_pairs(self) -> int:
        return sum(len(r.pairs) for r in self.sessions.values()) + len(
            self.pair_results
        )

    @property
    def total_ev_calls(self) -> int:
        return sum(r.total_ev_calls for r in self.sessions.values()) + sum(
            p.stats.ev_calls for p in self.pair_results
        )

    @property
    def total_ev_calls_saved(self) -> int:
        return sum(r.total_ev_calls_saved for r in self.sessions.values()) + sum(
            p.stats.ev_calls_saved for p in self.pair_results
        )

    @property
    def certified_pairs(self) -> int:
        return sum(r.certified_pairs for r in self.sessions.values()) + sum(
            1 for p in self.pair_results if p.certified
        )

    def summary(self) -> str:
        lines = []
        for client in sorted(self.sessions):
            r = self.sessions[client]
            lines.append(
                f"client {client}: {len(r.pairs)} pairs, "
                f"{r.certified_pairs} certified, {r.total_ev_calls} EV calls, "
                f"{r.total_ev_calls_saved} saved"
            )
        lines.append(
            f"service: {self.total_pairs} pairs "
            f"({self.certified_pairs} certified, {self.reused_pairs} reused), "
            f"{self.total_ev_calls} EV calls, "
            f"{self.total_ev_calls_saved} saved, "
            f"{len(self.errors)} errors"
        )
        return "\n".join(lines)


class _ClientState:
    """One client's session plus the FIFO gate serializing its jobs.

    ``tickets`` hands each submitted job a sequence number; only the job
    whose number equals ``next_ticket`` may run.  A worker that dequeues a
    job that is not ready does **not** wait — it *parks* the job on the
    client and serves other work; whichever worker finishes the client's
    running job advances the ticket and runs the parked successor itself.
    Workers therefore never block on the gate, so one client's burst can
    never stall the pool for other clients, and there is nothing to
    deadlock: every enqueued job is either running, parked behind exactly
    one running job, or in the queue.
    """

    def __init__(self, session: VersionChainSession):
        self.session = session
        self.lock = threading.Lock()
        # held across ticket allocation AND queue insertion, so a ticket
        # abandoned on enqueue failure can never have a later ticket already
        # issued (the abandon fast-forward below stays race-free)
        self.submit_lock = threading.Lock()
        self.tickets = 0     # next ticket to hand out (submit side)
        self.next_ticket = 0  # next ticket allowed to run (worker side)
        self.abandoned: set = set()  # tickets whose job never entered the queue
        self.parked: Dict[int, "_Job"] = {}  # dequeued too early, by ticket


@dataclass
class _Job:
    client: Optional[_ClientState]   # None: stateless one-shot pair job
    ticket: int
    fn: Callable[[], object]
    future: Future


def _fast_forward(state: _ClientState) -> None:
    """Advance past abandoned tickets (caller holds ``state.lock``)."""
    while state.next_ticket in state.abandoned:
        state.abandoned.discard(state.next_ticket)
        state.next_ticket += 1


_STOP = object()


class VerificationService:
    """Multiplexes concurrent verification sessions over one shared cache.

    Parameters
    ----------
    config:
        The ``VeerConfig`` every session (and one-shot verifier) is built
        from.  Its ``max_workers`` still controls *intra-pair* window
        parallelism; ``workers`` below is the *inter-client* pool.
    registry:
        EV registry sessions resolve their EVs from (default roster).
    cache:
        A shared ``VerdictCache``; defaults to one built from
        ``config.cache_path`` (in-memory when unset).
    workers:
        Worker threads draining the job queue — the service's concurrency.
    queue_size:
        Bound of the job queue; ``submit`` blocks (backpressure) or raises
        ``ServiceBusy`` when full.
    share_pair_verdicts:
        Attach a shared ``PairVerdictCache``: content-identical pairs
        submitted by different clients (or repeatedly by one) are decided
        once — concurrent duplicates coalesce onto a single search whose
        verdict and certificate every waiter reuses.  On by default; turn
        off to force every client to run its own searches.
    materialization_store:
        A shared, thread-safe ``repro.engine.MaterializationStore``
        (both built-in stores lock internally).  Enables execute-with-reuse
        per client: ``submit(..., sources=...)`` executes the version's
        changed cone only, seeded from the pair certificate's frontier —
        equivalent results materialized by *any* client's chain are
        content-addressed, so clients evolving the same pipeline share
        tables the same way they share verdicts.
    """

    def __init__(
        self,
        config: Optional[VeerConfig] = None,
        *,
        registry: Optional[EVRegistry] = None,
        cache: Optional[VerdictCache] = None,
        workers: int = 4,
        queue_size: int = 64,
        keep_certificates: bool = True,
        share_pair_verdicts: bool = True,
        materialization_store=None,
    ):
        if workers < 1:
            raise ValueError("need at least one worker")
        if queue_size < 1:
            raise ValueError("queue_size must be positive")
        self.config = config if config is not None else VeerConfig()
        self.registry = registry
        # config.shared_tier="remote" attaches the FileTier as a second
        # cache level (same tier a VerificationFleet's workers mount, so a
        # service and a fleet can share one directory of verdicts/tables);
        # explicitly passed caches always win over tier construction
        tier = None
        if self.config.shared_tier == "remote":
            tier = make_tier(
                self.config.shared_tier,
                self.config.tier_dir,
                ttl_seconds=self.config.tier_ttl_seconds,
                byte_budget=self.config.tier_byte_budget,
            )
        self.tier = tier
        if cache is not None:
            self.cache = cache
        elif tier is not None:
            self.cache = TieredVerdictCache(
                tier,
                self.config.cache_path,
                max_entries=self.config.cache_max_entries,
            )
        else:
            self.cache = VerdictCache(
                self.config.cache_path,
                max_entries=self.config.cache_max_entries,
            )
        if not share_pair_verdicts:
            self.pair_cache = None
        elif tier is not None:
            self.pair_cache = TieredPairCache(tier, registry=registry)
        else:
            self.pair_cache = PairVerdictCache()
        self.materialization_store = materialization_store
        self.keep_certificates = keep_certificates
        self._queue: "queue.Queue[object]" = queue.Queue(maxsize=queue_size)
        self._clients: Dict[str, _ClientState] = {}
        self._lock = threading.Lock()
        # _submitting: submits in flight between their closed-check and
        # their enqueue; _pending: enqueued-but-unfinished jobs (queued,
        # parked, or running — queue.join() can't serve here because parked
        # jobs leave the queue before they run).  drain() and close() wait
        # for BOTH to reach zero on one shared condition, so neither can
        # return while a submit it raced is still materializing its job.
        self._submitting = 0
        self._pending = 0
        self._progress = threading.Condition(self._lock)
        # unsettled futures only; drain() folds settled ones into the
        # persistent aggregates below and drops them
        self._pair_futures: List[Future] = []
        self._chain_futures: List[Tuple[str, Future]] = []
        self._errors: List[str] = []
        self._pair_results: List[VerificationResult] = []
        self._oneshot_veers: List[object] = []  # per-worker thread-local Veers
        self._closed = False
        self._local = threading.local()  # per-worker Veer for one-shot pairs
        self._workers = [
            threading.Thread(
                target=self._worker_loop, name=f"veer-svc-{i}", daemon=True
            )
            for i in range(workers)
        ]
        for t in self._workers:
            t.start()

    # -- public API ----------------------------------------------------------
    def session(self, client_id: str) -> VersionChainSession:
        """The (auto-created) chain session behind ``client_id``."""
        return self._client(client_id).session

    def submit(
        self,
        client_id: str,
        version: DataflowDAG,
        mapping: Optional[EditMapping] = None,
        *,
        sources=None,
        block: bool = True,
        timeout: Optional[float] = None,
    ) -> "Future[Optional[PairReport]]":
        """Enqueue a version for ``client_id``'s chain; returns a Future.

        The Future resolves to the pair's ``PairReport`` (None for the
        client's first version).  Jobs of one client run strictly in
        submission order; the call blocks when the queue is full unless
        ``block=False``/``timeout`` asks for ``ServiceBusy`` instead.
        ``sources`` opts this version into execute-with-reuse (needs the
        service's ``materialization_store``; see ``VersionChainSession``).
        """
        state = self._client(client_id)  # built outside the service lock
        with self._lock:
            if self._closed:
                raise ServiceClosed("service is closed")
            self._submitting += 1
        future: Future = Future()
        try:
            # ticket allocation and queue insertion must be one atomic step
            # per client: if they could interleave, a later ticket could
            # enter the queue first and every worker would wait on a job
            # still queued behind it.  The per-client lock serializes
            # same-client submitters only; other clients are unaffected.
            with state.submit_lock:
                ticket = state.tickets
                state.tickets += 1
                job = _Job(
                    client=state,
                    ticket=ticket,
                    fn=lambda: state.session.submit(
                        version, mapping, sources=sources
                    ),
                    future=future,
                )
                self._enqueue(job, block, timeout)
            with self._lock:
                self._chain_futures.append((client_id, future))
        finally:
            with self._lock:
                self._submitting -= 1
                self._progress.notify_all()
        return future

    def submit_pair(
        self,
        P: DataflowDAG,
        Q: DataflowDAG,
        mapping: Optional[EditMapping] = None,
        *,
        block: bool = True,
        timeout: Optional[float] = None,
    ) -> "Future[VerificationResult]":
        """One-shot pair verification on the shared cache (no session state,
        any worker, no ordering constraint)."""
        with self._lock:
            if self._closed:
                raise ServiceClosed("service is closed")
            self._submitting += 1
        future: Future = Future()
        try:
            job = _Job(
                client=None,
                ticket=0,
                fn=lambda: self._verify_pair(P, Q, mapping),
                future=future,
            )
            self._enqueue(job, block, timeout)  # rejected jobs are never tracked
            with self._lock:
                self._pair_futures.append(future)
        finally:
            with self._lock:
                self._submitting -= 1
                self._progress.notify_all()
        return future

    def drain(self) -> ServiceReport:
        """Block until every submitted job has run; aggregate the results.

        Safe to call repeatedly — each call reports the cumulative state.
        Job exceptions are collected into ``errors`` (they are also set on
        the individual Futures); they never kill a worker.  Settled futures
        are folded into compact per-service aggregates and dropped, so a
        long-running service does not retain one Future per job ever
        submitted (nor rescan its whole history on every drain).
        """
        with self._lock:
            # wait for in-flight submits too: a submit past its closed-check
            # but before its enqueue is work this drain must cover
            while self._submitting or self._pending:
                self._progress.wait()
        with self._lock:
            # fold settled futures into the persistent aggregates, keep
            # only the (rare) ones whose tracking append raced the worker
            pending_chain = []
            for client_id, f in self._chain_futures:
                if not f.done():
                    pending_chain.append((client_id, f))
                    continue
                if f.cancelled():
                    continue  # caller withdrew the job; not a service error
                exc = f.exception()
                if exc is not None:
                    self._errors.append(f"{client_id}: {exc!r}")
            self._chain_futures = pending_chain
            pending_pair = []
            for f in self._pair_futures:
                if not f.done():
                    pending_pair.append(f)
                    continue
                if f.cancelled():
                    continue  # caller withdrew the job; not a service error
                exc = f.exception()
                if exc is not None:
                    self._errors.append(f"pair: {exc!r}")
                else:
                    self._pair_results.append(f.result())
            self._pair_futures = pending_pair
            # snapshot: the live ChainReports keep growing if the caller
            # submits after drain, so hand out copies like errors/pair_results
            sessions = {
                cid: ChainReport(
                    pairs=list(st.session.report().pairs),
                    initial_exec=st.session.report().initial_exec,
                )
                for cid, st in self._clients.items()
            }
            errors = list(self._errors)
            pair_results = list(self._pair_results)
        return ServiceReport(
            sessions=sessions,
            pair_results=pair_results,
            errors=errors,
            cache_stats=self.cache.stats(),
            pair_cache_stats=(
                self.pair_cache.stats() if self.pair_cache is not None else {}
            ),
        )

    def save(self) -> None:
        """Persist the shared verdict cache (atomic snapshot)."""
        self.cache.save()

    def close(self, *, save: bool = True) -> None:
        """Drain, stop the workers, optionally persist the cache."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            # wait out submits already past their closed-check and all
            # enqueued jobs: after this, no job can land behind the stop
            # sentinels and nothing is left queued, parked, or running
            while self._submitting or self._pending:
                self._progress.wait()
        for _ in self._workers:
            self._queue.put(_STOP)
        for t in self._workers:
            t.join()
        # defensive sweep: the barriers above mean no job should be able to
        # land behind the stop sentinels, but if one ever does, fail its
        # future instead of leaving it pending forever
        while True:
            try:
                job = self._queue.get_nowait()
            except queue.Empty:
                break
            if job is not _STOP and not job.future.done():
                job.future.set_exception(ServiceClosed("service closed"))
        for state in self._clients.values():
            state.session.veer.close()
        for veer in self._oneshot_veers:
            veer.close()  # per-worker verifiers' window pools
        if save:
            self.save()

    def __enter__(self) -> "VerificationService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- internals -----------------------------------------------------------
    def _client(self, client_id: str) -> _ClientState:
        """Get-or-create a client's state.  Called WITHOUT the service lock:
        session construction (config validation, EV instantiation, verifier
        wiring) must not stall unrelated clients' submits behind the global
        lock.  Racing creators build two sessions; ``setdefault`` keeps the
        first and the loser's fresh, never-used session is discarded."""
        with self._lock:
            state = self._clients.get(client_id)
        if state is not None:
            return state
        session = VersionChainSession(
            config=self.config,
            registry=self.registry,
            cache=self.cache,
            keep_certificates=self.keep_certificates,
            pair_cache=self.pair_cache,
            materialization_store=self.materialization_store,
        )
        with self._lock:
            return self._clients.setdefault(client_id, _ClientState(session))

    def _enqueue(self, job: _Job, block: bool, timeout: Optional[float]) -> None:
        # count the job BEFORE it can possibly run: a worker could dequeue
        # and finish it between put and a later increment, letting a racing
        # drain() observe a stale count (hang, or return before the job ran)
        with self._lock:
            self._pending += 1
        try:
            self._queue.put(job, block=block, timeout=timeout)
        except BaseException as e:
            with self._lock:
                self._pending -= 1
                self._progress.notify_all()
            # the job never entered the queue (queue full, or e.g. a
            # KeyboardInterrupt out of a blocking put): mark its ticket
            # abandoned so the gate skips it and the client's later jobs
            # are not wedged.  submit_lock is held here, so no later ticket
            # exists yet and nothing can be parked behind this one.
            if job.client is not None:
                with job.client.lock:
                    job.client.abandoned.add(job.ticket)
            if isinstance(e, queue.Full):
                job.future.set_exception(ServiceBusy("job queue is full"))
                raise ServiceBusy("job queue is full") from None
            if isinstance(e, Exception):
                job.future.set_exception(e)  # defensive: never leave it pending
            raise

    def _verify_pair(
        self,
        P: DataflowDAG,
        Q: DataflowDAG,
        mapping: Optional[EditMapping],
    ) -> VerificationResult:
        if self.pair_cache is None:
            return self._verify_pair_uncoalesced(P, Q, mapping)

        def compute():
            r = self._verify_pair_uncoalesced(P, Q, mapping)
            return r.verdict, r.stats, r.certificate

        key = self.pair_cache.make_key(P, Q, self.config.semantics, mapping)
        verdict, stats, certificate, reused = self.pair_cache.compute_or_reuse(
            key, compute, pair=(P, Q)
        )
        return VerificationResult(
            verdict=verdict,
            stats=stats,
            certificate=certificate,
            config=self.config,
            reused=reused,
        )

    def _verify_pair_uncoalesced(
        self,
        P: DataflowDAG,
        Q: DataflowDAG,
        mapping: Optional[EditMapping],
    ) -> VerificationResult:
        veer = getattr(self._local, "veer", None)
        if veer is None:
            # one verifier per worker thread: fresh EV instances, so only
            # the verdict cache (which has its own lock) is ever shared
            veer = self.config.build(self.registry, cache=self.cache)
            self._local.veer = veer
            with self._lock:
                self._oneshot_veers.append(veer)  # closed with the service
        return verify(P, Q, self.config, mapping=mapping, veer=veer)

    def _worker_loop(self) -> None:
        while True:
            job = self._queue.get()
            if job is _STOP:
                return
            self._run(job)

    def _run(self, job: _Job) -> None:
        state = job.client
        if state is None:
            self._execute(job)
            return
        with state.lock:
            _fast_forward(state)
            if state.next_ticket != job.ticket:
                # not this job's turn: park it and serve other work — the
                # worker finishing the client's running job picks it up.
                # Never blocks, so a burst from one client cannot pin
                # multiple workers while only one of its jobs can run.
                state.parked[job.ticket] = job
                return
        # only the matching ticket reaches here, so the session is never
        # entered by two threads at once; after each job, continue with the
        # client's parked successor (if any) on this same worker
        while job is not None:
            self._execute(job)
            with state.lock:
                state.next_ticket += 1
                _fast_forward(state)
                job = state.parked.pop(state.next_ticket, None)

    def _execute(self, job: _Job) -> None:
        try:
            # a future cancelled while queued/parked must be skipped, not
            # run: set_result on a cancelled future raises InvalidStateError
            # and would kill the worker thread.  For a chain job the ticket
            # still advances (in _run), so the client's later jobs proceed —
            # cancelling removes that version from the chain, cleanly.
            if job.future.set_running_or_notify_cancel():
                try:
                    result = job.fn()
                except BaseException as e:
                    job.future.set_exception(e)
                else:
                    job.future.set_result(result)
        finally:
            with self._lock:
                self._pending -= 1
                self._progress.notify_all()
