"""Deterministic synthetic version chains for the chain-verification service.

Models the paper's §1 iterative-analytics workload: an analyst maintains a
dashboard of ``branches`` parallel per-topic pipelines (identical shape,
different sources) and keeps applying small local rewrites — reordering the
two filters of a branch, or inserting/removing a redundant filter.  Every
version is 1-2 changes away from its predecessor, operator ids are stable
(the tracked/identity edit mapping applies), and every consecutive pair is
equivalent by construction.

Because the branches are isomorphic and the rewrites recur, the chain is the
canonical stress test for cross-pair verdict reuse: the *first* occurrence of
each rewrite direction pays EV calls; every later occurrence — on any branch,
in any later pair (or session) — is a fingerprint cache hit.

Determinism: this module uses **no** random state at all (module-level or
otherwise) — ``make_chain`` is a pure function of its arguments.  Randomized
session generation lives in ``repro.workload`` (one explicit
``random.Random`` per session, same-seed ⇒ byte-identical; see
``tests/test_workload_stress.py``); this synthetic chain stays the fixed,
hand-analyzable counterpart the service unit tests reason about exactly.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import List, Optional, Tuple

from repro.core import dag as D
from repro.core.dag import DataflowDAG, Link, Operator
from repro.core.predicates import Pred

op = Operator.make

SCHEMA = ("a", "b", "c")


@dataclass(frozen=True)
class _BranchState:
    swapped: bool = False   # filter order: False = fa,fb ; True = fb,fa
    redundant: bool = False  # extra filter fe (implied by fb) present


def _branch(
    j: int, state: _BranchState, heavy: bool = False
) -> Tuple[List[Operator], List[Link]]:
    fa = op(f"fa{j}", D.FILTER, pred=Pred.cmp("a", ">", 2))
    fb = op(f"fb{j}", D.FILTER, pred=Pred.cmp("b", "<", 5))
    ops = [
        op(f"src{j}", D.SOURCE, schema=SCHEMA),
        fa,
        fb,
        op(f"proj{j}", D.PROJECT, cols=tuple((c, c) for c in SCHEMA)),
        op(f"sink{j}", D.SINK, semantics=D.BAG),
    ]
    order = [fb.id, fa.id] if state.swapped else [fa.id, fb.id]
    if state.redundant:
        # fe sits at the branch head and is implied by fb (b < 5 ⇒ b < 9),
        # so it is provably removable; placing it before the swap region
        # keeps the filter-swap windows isomorphic across branches
        ops.append(op(f"fe{j}", D.FILTER, pred=Pred.cmp("b", "<", 9)))
        order = [f"fe{j}"] + order
    tail = [f"proj{j}"]
    if heavy:
        # expensive, deterministic downstream: a per-row classifier and a
        # grouping aggregate, downstream of (and untouched by) the rewrites
        # — the regime where execution dominates verification and
        # materialization reuse pays (benchmarks/exec_bench.py)
        ops.append(
            op(f"cl{j}", D.CLASSIFIER, col="a", out="label",
               model="chain", classes=5)
        )
        ops.append(
            op(f"agg{j}", D.AGGREGATE, group_by=("label",),
               aggs=(("sum", "a", "sa"), ("count", "*", "n")))
        )
        tail += [f"cl{j}", f"agg{j}"]
    path = [f"src{j}"] + order + tail + [f"sink{j}"]
    links = [Link(a, b) for a, b in zip(path, path[1:])]
    return ops, links


def _build(states: List[_BranchState], heavy: bool = False) -> DataflowDAG:
    ops: List[Operator] = []
    links: List[Link] = []
    for j, st in enumerate(states):
        o, l = _branch(j, st, heavy)
        ops += o
        links += l
    return DataflowDAG(ops, links)


def make_chain(
    n_versions: int, branches: Optional[int] = None, heavy: bool = False
) -> List[DataflowDAG]:
    """A chain of ``n_versions`` dataflows, each 1-2 changes from the last.

    Pair k (k ≥ 1) swaps the two filters of branch ``(k-1) % branches`` —
    the same rewrite landing on a *fresh but isomorphic* branch each time,
    so every pair after the first re-poses window questions the first pair
    already paid for.  Every third pair additionally toggles the redundant
    head filter of the next branch over.  ``branches`` defaults to
    ``n_versions - 1`` (each branch is swapped at most once along the
    chain).  ``heavy=True`` appends an expensive classifier + aggregate
    tail to every branch (the execution-reuse benchmark's workload).
    Deterministic — same arguments, same chain.
    """
    if n_versions < 2:
        raise ValueError("a chain needs at least 2 versions")
    if branches is None:
        branches = n_versions - 1
    if branches < 1:
        raise ValueError("need at least one branch")
    states = [_BranchState() for _ in range(branches)]
    versions = [_build(states, heavy)]
    for k in range(1, n_versions):
        j = (k - 1) % branches
        states[j] = replace(states[j], swapped=not states[j].swapped)
        if k % 3 == 0:
            i = k % branches
            states[i] = replace(states[i], redundant=not states[i].redundant)
        versions.append(_build(states, heavy))
    return versions
