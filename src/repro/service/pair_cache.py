"""Pair-level verdict memoization with single-flight coalescing.

The window-level ``VerdictCache`` (``repro.core.ev.cache``) eliminates EV
*calls*, but each pair still pays the full decomposition search — pure
Python that dominates wall time once EV calls are cached.  At service scale
the same *whole pair* recurs constantly: many clients maintain copies of
the same pipeline, re-submit after a no-op edit, or replay a chain a
colleague already verified.  ``PairVerdictCache`` memoizes decided pairs at
that granularity, keyed by the same content digest that binds certificates
(``repro.api.certificate.pair_digest`` over ``(P, Q, semantics)``) plus the
explicitly requested edit mapping — so a hit returns the *original run's
certificate*, which by construction replays green against the pair.

Soundness: digest equality means the two DAGs are content-identical
(signatures cover operators, links, parameters), so the cached verdict and
certificate apply verbatim.  Unknown verdicts are never cached — they can
be budget-dependent and carry no certificate.

Concurrency: ``acquire`` implements *single-flight* — when N threads miss
on the same key simultaneously, exactly one becomes the owner and computes
while the rest block until the owner publishes (or abandons, after which
one waiter takes over).  The owner never waits on anyone, so coalescing
cannot deadlock.  This is what turns N identical concurrent chains into
one chain's worth of search work (see benchmarks/service_bench.py).
"""

from __future__ import annotations

import hashlib
import json
import threading
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

from repro.api.certificate import Certificate, pair_digest
from repro.core.dag import DataflowDAG
from repro.core.edits import EditMapping
from repro.core.verifier import VeerStats

#: (pair digest, raw per-side digests when the canonical digest cannot tell
#: the sides apart, explicitly requested mapping or None for the default)
PairKey = Tuple[
    str, Optional[Tuple[str, str]], Optional[Tuple[Tuple[str, str], ...]]
]


def _raw_dag_digest(dag: DataflowDAG) -> str:
    """sha256 of the *raw* serialized DAG — the un-canonicalized operator
    forms a certificate payload stores (``dag_to_dict``), so two versions
    that differ only by a canonicalized rewrite (e.g. a scaled predicate)
    get distinct raw digests even though their ``content_digest``s match.
    Memoized on the DAG instance; deterministic across processes."""
    d = getattr(dag, "_raw_pair_cache_digest", None)
    if d is None:
        from repro.api.serialize import dag_to_dict

        blob = json.dumps(dag_to_dict(dag), sort_keys=True,
                          separators=(",", ":"))
        d = hashlib.sha256(blob.encode()).hexdigest()[:32]
        dag._raw_pair_cache_digest = d
    return d


@dataclass(frozen=True)
class PairEntry:
    """One decided pair: the verdict, its certificate, and what the
    original run paid — so hits can account the work they avoided."""

    verdict: bool
    certificate: Optional[Certificate]
    ev_calls_avoided: int     # original ev_calls + ev_calls_saved
    ev_time_avoided: float    # original ev_time + ev_time_saved


class PairVerdictCache:
    """Thread-safe ``PairKey -> PairEntry`` map with single-flight misses.

    Bounded: entries carry full certificates (serialized window payloads),
    so an unbounded map would grow with workload diversity for the life of
    a service.  When ``max_entries`` is exceeded the oldest entry is
    evicted (FIFO — recurring pairs are re-decided and re-inserted, which
    in practice keeps the hot set resident).
    """

    def __init__(self, max_entries: int = 65_536) -> None:
        if max_entries < 1:
            raise ValueError("max_entries must be positive")
        self.max_entries = max_entries
        self._lock = threading.Lock()
        self._entries: Dict[PairKey, PairEntry] = {}
        self._inflight: Dict[PairKey, threading.Event] = {}
        # keys whose owner abandoned (Unknown verdict): coalescing is
        # disabled for them, otherwise N concurrent submissions of an
        # undecidable pair would run their N searches strictly one after
        # another — worse than no coalescing at all
        self._abandoned: set = set()
        self.hits = 0
        self.misses = 0
        self.coalesced = 0  # lookups that waited for an in-flight owner

    @staticmethod
    def make_key(
        P: DataflowDAG,
        Q: DataflowDAG,
        semantics: str,
        mapping: Optional[EditMapping],
    ) -> PairKey:
        """Content key: certificate-binding digest + the pinned mapping.

        The mapping is part of the key because an explicit mapping changes
        which verdict the verifier reports (a False under mapping m is not
        a False under the default mapping search); ``None`` — the common
        case — keys the verifier's own mapping choice.

        When the two sides share one ``content_digest`` (a revert pair
        whose edit was a canonicalized rewrite), the pair digest is the
        same for (P, Q) and (Q, P) — but the cached certificate's payload
        stores the raw operator forms, so serving the swapped entry would
        change certificate bytes versus a cache-less run.  Raw per-side
        digests disambiguate exactly that case; everywhere else they are
        ``None`` and the hit behavior is unchanged.
        """
        raw = None
        if P.content_digest() == Q.content_digest():
            raw = (_raw_dag_digest(P), _raw_dag_digest(Q))
        return (
            pair_digest(P, Q, semantics),
            raw,
            mapping.p_to_q if mapping is not None else None,
        )

    def acquire(self, key: PairKey) -> Tuple[Optional[PairEntry], bool]:
        """``(entry, owner)``: a cached entry (owner False), or a miss the
        caller now owns (entry None, owner True — the caller MUST follow up
        with ``publish`` or ``abandon``).  Threads that miss while another
        owner is computing block here until the owner resolves."""
        while True:
            with self._lock:
                entry = self._entries.get(key)
                if entry is not None:
                    self.hits += 1
                    return entry, False
                if key in self._abandoned:
                    # known-undecidable: every caller computes immediately
                    # and in parallel (a later publish lifts the marker)
                    self.misses += 1
                    return None, True
                event = self._inflight.get(key)
                if event is None:
                    self._inflight[key] = threading.Event()
                    self.misses += 1
                    return None, True
                self.coalesced += 1
            event.wait()

    def compute_or_reuse(
        self,
        key: PairKey,
        compute: Callable[[], Tuple[Optional[bool], VeerStats, Optional[Certificate]]],
        *,
        pair: Optional[Tuple[DataflowDAG, DataflowDAG]] = None,
    ) -> Tuple[Optional[bool], VeerStats, Optional[Certificate], bool]:
        """The whole single-flight protocol in one place (both the chain
        session and the service's one-shot path go through here, so the
        invariants — never cache Unknown, abandon on *any* failure,
        hit-stats synthesis — cannot drift between callers).

        ``compute`` runs the actual verification and returns
        ``(verdict, stats, certificate)``.  Returns the same triple plus
        ``reused``; a reused result carries synthesized stats accounting
        only the avoided work.

        ``pair`` is the ``(P, Q)`` the key was made from.  This in-memory
        cache has no use for it (digest equality already binds entries to
        content-identical pairs); the tier-backed subclass
        (``repro.service.remote.adapters.TieredPairCache``) requires it to
        replay certificates before serving hits that crossed a process
        boundary.
        """
        del pair  # entries here were written by this process: trusted
        entry, _owner = self.acquire(key)
        if entry is not None:
            stats = VeerStats(
                verdict=entry.verdict,
                ev_calls_saved=entry.ev_calls_avoided,
                ev_time_saved=entry.ev_time_avoided,
            )
            return entry.verdict, stats, entry.certificate, True
        try:
            verdict, stats, certificate = compute()
        except BaseException:
            self.abandon(key)  # waiters re-elect an owner; nothing cached
            raise
        if verdict is None:
            # Unknown is budget-dependent and uncertifiable: never cache it
            self.abandon(key)
        else:
            self.publish(
                key,
                PairEntry(
                    verdict=verdict,
                    certificate=certificate,
                    ev_calls_avoided=stats.ev_calls + stats.ev_calls_saved,
                    ev_time_avoided=stats.ev_time + stats.ev_time_saved,
                ),
            )
        return verdict, stats, certificate, False

    def peek(self, key: PairKey) -> Optional[PairEntry]:
        """Non-coalescing lookup (no ownership, no waiting, no stats)."""
        with self._lock:
            return self._entries.get(key)

    def publish(self, key: PairKey, entry: PairEntry) -> None:
        """Store the owner's result and release every coalesced waiter."""
        with self._lock:
            self._entries[key] = entry
            while len(self._entries) > self.max_entries:
                self._entries.pop(next(iter(self._entries)))  # FIFO eviction
            self._abandoned.discard(key)
            event = self._inflight.pop(key, None)
        if event is not None:
            event.set()

    def abandon(self, key: PairKey) -> None:
        """Owner gives up (Unknown verdict or exception): wake the waiters.
        The key is marked so future ``acquire``s skip coalescing — waiters
        all become owners and recompute *concurrently* rather than
        serializing N hopeless searches behind one event."""
        with self._lock:
            self._abandoned.add(key)
            while len(self._abandoned) > self.max_entries:
                self._abandoned.pop()  # keep the marker set bounded too
            event = self._inflight.pop(key, None)
        if event is not None:
            event.set()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def stats(self) -> Dict[str, object]:
        with self._lock:
            return {
                "entries": len(self._entries),
                "hits": self.hits,
                "misses": self.misses,
                "coalesced": self.coalesced,
            }
