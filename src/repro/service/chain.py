"""Version-chain verification service (paper §1 workload, ROADMAP north star).

Iterative analytics produces *chains* of dataflow versions: v1 → v2 → … → vn,
each a handful of edits from its predecessor.  ``Veer.verify`` answers one
pair; a ``VersionChainSession`` answers the whole chain while amortizing EV
cost across pairs through the canonical-fingerprint verdict cache
(``repro.core.ev.cache``): a window isomorphic to one decided for *any*
earlier pair — or persisted by an earlier session — resolves without an EV
call.  This is the GEqO/EqDAC observation (cache and share semantic
equivalence sub-results) applied to Veer's windowed decomposition search.

Every decided pair carries a replayable ``repro.api.Certificate`` — cached
cross-session verdicts are auditable evidence, not trust-me (see
``repro.api.certificate``); ``ChainReport.summary()`` shows which pairs are
certificate-backed.

Typical use::

    from repro.api import VeerConfig

    session = VersionChainSession(
        config=VeerConfig(cache_path="~/.veer/verdicts.json")
    )
    session.submit(v1)                  # first version: nothing to verify
    report = session.submit(v2)         # verifies (v1, v2)
    report.certificate.replay()         # audit the verdict, no search
    report = session.submit(v3)         # verifies (v2, v3), reusing verdicts
    print(session.report().summary())
    session.save()                      # persist verdicts for the next session

or, batch-style::

    report = verify_chain([v1, v2, ..., vn])
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from repro.api.certificate import Certificate, certificate_from_evidence
from repro.api.config import VeerConfig
from repro.api.registry import EVRegistry
from repro.core import dag as D
from repro.core.dag import DataflowDAG
from repro.core.edits import EditMapping
from repro.core.ev.base import BaseEV
from repro.core.ev.cache import VerdictCache
from repro.core.frontier import FrontierError, ReuseFrontier, compute_reuse_frontier
from repro.core.verifier import Veer, VeerStats, make_veer_plus
from repro.engine.executor import ExecStats, ExecutionPlan
from repro.engine.store import MaterializationStore
from repro.engine.table import Table
from repro.service.pair_cache import PairVerdictCache


@dataclass
class PairReport:
    """Verification outcome for one consecutive pair of the chain."""

    index: int                      # pair k verifies (version k-1, version k)
    verdict: Optional[bool]         # True / False / None (Unknown)
    wall_time: float
    stats: VeerStats
    certificate: Optional[Certificate] = None
    # whether the verdict WAS certificate-backed — stays True even when a
    # session with keep_certificates=False drops the payload after returning
    # it to the submit caller
    certified: bool = False
    # verdict + certificate reused wholesale from a PairVerdictCache hit
    # (no search ran for this pair; stats carry only the avoided work)
    reused: bool = False
    # execute-with-reuse mode (sources= passed to submit): accounting for
    # this version's partial execution, the certificate-derived frontier
    # that seeded it, and the sink tables (results are handed to the
    # submit caller only — the session-lifetime report drops them)
    exec_stats: Optional[ExecStats] = None
    frontier: Optional[ReuseFrontier] = None
    results: Optional[Dict[str, Table]] = None

    def __post_init__(self) -> None:
        if self.certificate is not None:
            self.certified = True

    @property
    def equivalent(self) -> bool:
        return self.verdict is True

    @property
    def ev_calls(self) -> int:
        return self.stats.ev_calls

    @property
    def cache_hits(self) -> int:
        return self.stats.cache_hits

    @property
    def ev_calls_saved(self) -> int:
        return self.stats.ev_calls_saved

    def row(self) -> str:
        v = {True: "EQ", False: "NEQ", None: "UNK"}[self.verdict]
        cert = "cert" if self.certified else "----"
        line = (
            f"pair {self.index:>3}: {v:>3}  {cert}  ev_calls={self.ev_calls:<4} "
            f"cache_hits={self.cache_hits:<4} saved={self.ev_calls_saved:<4} "
            f"{self.wall_time * 1e3:8.1f} ms"
            + ("  reused" if self.reused else "")
        )
        if self.exec_stats is not None:
            e = self.exec_stats
            line += (
                f"  exec[{e.ops_executed}/{e.ops_total} ops, "
                f"{e.ops_reused} reused, {e.tables_served} served]"
            )
            if e.ops_delta:
                line += (
                    f"  delta[{e.ops_delta} ops, "
                    f"{e.delta_rows_processed} rows]"
                )
        return line


@dataclass
class ChainReport:
    """Aggregate over all pairs verified so far in a session."""

    pairs: List[PairReport] = field(default_factory=list)
    # execute-with-reuse: accounting for the chain's FIRST version (it has
    # no pair — v1 executes fully and materializes the seed corpus)
    initial_exec: Optional[ExecStats] = None

    @property
    def exec_stats_list(self) -> List[ExecStats]:
        out = [self.initial_exec] if self.initial_exec is not None else []
        out.extend(p.exec_stats for p in self.pairs if p.exec_stats is not None)
        return out

    @property
    def total_ops_executed(self) -> int:
        return sum(e.ops_executed for e in self.exec_stats_list)

    @property
    def total_ops_reused(self) -> int:
        return sum(e.ops_reused for e in self.exec_stats_list)

    @property
    def total_tables_served(self) -> int:
        return sum(e.tables_served for e in self.exec_stats_list)

    @property
    def total_ops(self) -> int:
        return sum(e.ops_total for e in self.exec_stats_list)

    @property
    def total_ops_delta(self) -> int:
        """Operators whose outputs came from delta rules, chain-wide."""
        return sum(e.ops_delta for e in self.exec_stats_list)

    @property
    def total_delta_rows_processed(self) -> int:
        """Delta rows (inserts + deletes) the delta rules touched — the
        O(|Δ|) work that replaced full re-execution."""
        return sum(e.delta_rows_processed for e in self.exec_stats_list)

    @property
    def total_recompute_time_saved(self) -> float:
        """Recorded original compute cost of every table served instead of
        recomputed (store-recorded seconds)."""
        return sum(e.recompute_time_saved for e in self.exec_stats_list)

    @property
    def executed_fraction(self) -> float:
        """Share of all chain operators that actually ran ``execute_op`` —
        the headline the exec benchmark bounds (≤ 0.30 on the 12-version
        workload with a warm verdict cache)."""
        return self.total_ops_executed / max(1, self.total_ops)

    @property
    def total_ev_calls(self) -> int:
        return sum(p.ev_calls for p in self.pairs)

    @property
    def total_cache_hits(self) -> int:
        return sum(p.cache_hits for p in self.pairs)

    @property
    def total_ev_calls_saved(self) -> int:
        return sum(p.ev_calls_saved for p in self.pairs)

    @property
    def total_wall_time(self) -> float:
        return sum(p.wall_time for p in self.pairs)

    @property
    def verdicts(self) -> List[Optional[bool]]:
        return [p.verdict for p in self.pairs]

    @property
    def certified_pairs(self) -> int:
        return sum(1 for p in self.pairs if p.certified)

    @property
    def reused_pairs(self) -> int:
        """Pairs answered wholesale from the shared pair-verdict cache."""
        return sum(1 for p in self.pairs if p.reused)

    @property
    def certified_fraction(self) -> float:
        """Share of *decided* (True/False) pairs backed by a certificate."""
        decided = [p for p in self.pairs if p.verdict is not None]
        if not decided:
            return 0.0
        return sum(1 for p in decided if p.certified) / len(decided)

    def summary(self) -> str:
        lines = [p.row() for p in self.pairs]
        lines.append(
            f"chain: {len(self.pairs)} pairs, "
            f"{self.certified_pairs} certificate-backed, "
            f"{self.total_ev_calls} EV calls, "
            f"{self.total_cache_hits} cache hits, "
            f"{self.total_ev_calls_saved} calls saved, "
            f"{self.total_wall_time * 1e3:.1f} ms"
        )
        if self.exec_stats_list:
            lines.append(
                f"exec:  {self.total_ops_executed}/{self.total_ops} ops "
                f"executed ({100.0 * self.executed_fraction:.0f}%), "
                f"{self.total_ops_reused} reused, "
                f"{self.total_tables_served} tables served"
            )
        if self.total_ops_delta:
            lines.append(
                f"delta: {self.total_ops_delta} ops via delta rules, "
                f"{self.total_delta_rows_processed} delta rows, "
                f"{self.total_recompute_time_saved * 1e3:.1f} ms "
                f"recompute saved"
            )
        return "\n".join(lines)


class VersionChainSession:
    """Stateful chain-verification service around a cache-backed ``Veer``.

    Each ``submit`` verifies the new version against the previous one; all
    pairs share one ``VerdictCache`` (optionally persisted at ``cache_path``
    and/or shared with a ``ReuseManager``'s store directory), so pair *k*
    pays EV cost only for windows no earlier pair or session has decided.
    """

    def __init__(
        self,
        evs: Optional[Sequence[BaseEV]] = None,
        *,
        config: Optional[VeerConfig] = None,
        registry: Optional[EVRegistry] = None,
        cache: Optional[VerdictCache] = None,
        cache_path: Optional[str] = None,
        semantics: Optional[str] = None,
        veer: Optional[Veer] = None,
        keep_certificates: bool = True,
        pair_cache: Optional["PairVerdictCache"] = None,
        materialization_store: Optional[MaterializationStore] = None,
        **veer_kw,
    ):
        """The preferred construction path is ``config=VeerConfig(...)``
        (EVs by name, resolved through ``registry``); ``evs``/``veer`` and
        ``**veer_kw`` remain as deprecated shims for pre-``repro.api``
        callers.  Cache precedence: explicit ``cache`` > ``cache_path`` >
        ``config.cache_path`` > in-memory.

        ``keep_certificates=False`` drops certificate payloads from the
        session-lifetime report after each ``submit`` returns (the caller
        still receives the full certificate; ``PairReport.certified`` stays
        truthful) — for very long monitoring sessions whose report must not
        accumulate per-pair window payloads.

        ``pair_cache`` (a shared ``repro.service.pair_cache
        .PairVerdictCache``) short-circuits whole pairs already decided by
        any session sharing the cache: a content-digest hit reuses the
        original verdict *and certificate* without running the search —
        this is how a ``VerificationService`` answers N clients evolving
        the same pipeline for one client's worth of work.

        ``materialization_store`` enables **execute-with-reuse**: pass
        ``sources=`` to ``submit`` and the session executes each version
        through an ``ExecutionPlan``, materializing operator outputs into
        the store and seeding every successor from the certificate-derived
        reuse frontier (``repro.core.frontier``) — v1 runs fully, each
        later version recomputes only its changed cone.  Seeding is taken
        only from exact-tier frontier entries whose content digests match,
        so the returned sink tables are bit-identical to a full
        re-execution; frontier reuse is only ever taken when the pair's
        certificate replays green against the pair."""
        if config is not None and (evs is not None or veer is not None or veer_kw):
            raise ValueError("pass either config or evs/veer/veer_kw, not both")
        if veer is not None and (evs is not None or veer_kw):
            raise ValueError("pass either veer or evs/veer_kw, not both")
        if cache is not None and cache_path is not None:
            raise ValueError("pass either cache or cache_path, not both")
        if config is None and evs is None and veer is None and not veer_kw:
            config = VeerConfig()
        if cache is None:
            path = cache_path if cache_path is not None else (
                config.cache_path if config is not None else None
            )
            # honor the config's LRU bound so long-lived sessions do not
            # accumulate verdict/validity entries without limit
            cache = VerdictCache(
                path,
                max_entries=(
                    config.cache_max_entries if config is not None else None
                ),
            )
        self.cache = cache
        self.config = config
        if config is not None:
            veer = config.build(registry, cache=cache)
        elif veer is None:
            # deprecated path: explicit EV instances and/or raw Veer kwargs
            # keep their pre-api semantics (forwarded to make_veer_plus)
            from repro.api.registry import default_registry

            evs = list(evs) if evs is not None else default_registry().build()
            veer = make_veer_plus(evs, **veer_kw)
        self.veer = veer.attach_cache(cache)
        if semantics is None:
            semantics = config.semantics if config is not None else D.BAG
        self.semantics = semantics
        # data plane for execute-with-reuse submits; plane-invariant bytes
        # keep store keys / frontier digests / certificates unchanged
        self.plane = config.plane if config is not None else "numpy"
        # how successor versions execute: full / reuse / delta (mode-invariant
        # sink bytes; "delta" falls back to the seeded reuse run whenever the
        # edit is not amenable or a required table left the store)
        self.exec_mode = config.exec_mode if config is not None else "reuse"
        self.keep_certificates = keep_certificates
        self.pair_cache = pair_cache
        self.store = materialization_store
        self._registry = registry
        # only the previous version is needed for the next pair; a long-lived
        # session must not accumulate every DAG it ever saw
        self._prev: Optional[DataflowDAG] = None
        self._prev_plan: Optional[ExecutionPlan] = None
        self.version_count = 0
        self._report = ChainReport()

    # -- service API ---------------------------------------------------------
    def submit(
        self,
        version: DataflowDAG,
        mapping: Optional[EditMapping] = None,
        *,
        sources: Optional[Dict[str, Table]] = None,
    ) -> Optional[PairReport]:
        """Append a version; verify it against the previous one.

        ``mapping`` is the tracked edit mapping from the previous version to
        this one (defaults to the id-stable identity mapping, the natural
        choice when the version-control layer assigns stable operator ids).
        Returns ``None`` for the first version (nothing to verify yet).

        ``sources`` (execute-with-reuse mode; needs a session
        ``materialization_store``) additionally *executes* the version:
        the first version runs fully, successors recompute only the cone
        the edit touched, seeded from exact-tier frontier entries of the
        pair's replay-green certificate.  The returned report then carries
        ``exec_stats``, the ``frontier``, and the sink ``results`` —
        including for the **first** version, which gets a report (verdict
        ``None``, nothing to verify) instead of the verify-only ``None``.
        """
        version.validate()
        if sources is not None and self.store is None:
            # checked before any session state moves: a rejected submit must
            # leave the chain exactly where it was
            raise ValueError(
                "execute-with-reuse needs a session materialization_store"
            )
        prev, self._prev = self._prev, version
        self.version_count += 1
        plan: Optional[ExecutionPlan] = None
        if sources is not None:
            plan = ExecutionPlan(version, sources, plane=self.plane)
        prev_plan, self._prev_plan = self._prev_plan, plan

        if prev is None:
            if plan is None:
                return None
            res = plan.run(store=self.store, materialize=True)
            self._report.initial_exec = res.stats
            return PairReport(
                index=0,
                verdict=None,
                wall_time=res.stats.wall_time,
                stats=VeerStats(),
                exec_stats=res.stats,
                results=res.results,
            )

        t0 = time.perf_counter()
        verdict, stats, certificate, reused = self._decide(prev, version, mapping)
        exec_stats = frontier = results = None
        if plan is not None:
            if self.exec_mode == "full":
                res = plan.run(store=self.store, materialize=True)
            else:
                frontier, seed_keys = self._frontier_seeds(
                    prev, version, certificate, verdict, prev_plan, plan
                )
                res = None
                if self.exec_mode == "delta" and frontier is not None:
                    res = self._try_delta(frontier, prev, prev_plan, plan)
                if res is None:
                    res = plan.run(
                        store=self.store, seed_keys=seed_keys,
                        materialize=True,
                    )
            exec_stats, results = res.stats, res.results
        report = PairReport(
            index=self.version_count - 1,
            verdict=verdict,
            wall_time=time.perf_counter() - t0,
            stats=stats,
            certificate=certificate,
            reused=reused,
            exec_stats=exec_stats,
            frontier=frontier,
            results=results,
        )
        # the session-lifetime report never accumulates sink tables; the
        # certificate/frontier payloads follow keep_certificates
        stored = dataclasses.replace(report, results=None)
        if not self.keep_certificates:
            stored = dataclasses.replace(stored, certificate=None, frontier=None)
        self._report.pairs.append(stored)
        return report

    def _frontier_seeds(
        self,
        prev: DataflowDAG,
        version: DataflowDAG,
        certificate: Optional[Certificate],
        verdict: Optional[bool],
        prev_plan: Optional[ExecutionPlan],
        plan: ExecutionPlan,
    ):
        """Certificate-gated seeding for this version's partial execution.

        Only a True verdict whose certificate **replays green bound to the
        pair** yields a frontier (``compute_reuse_frontier`` enforces it);
        only *exact-tier* entries are seeded, and each one additionally
        requires digest equality between the Q operator's cone (current
        sources folded in) and the P operator's materialized table — so a
        source rebinding or any mismatch falls back to recomputation and
        the executed results stay bit-identical to a full run.
        """
        if verdict is not True or certificate is None or prev_plan is None:
            return None, {}
        try:
            frontier = compute_reuse_frontier(
                certificate, prev, version, registry=self._registry
            )
        except FrontierError:
            return None, {}
        prev_digests = prev_plan.digests
        cur_digests = plan.digests
        seed_keys = {}
        for q_op, p_op in frontier.exact.items():
            key = prev_digests.get(p_op)
            if key is not None and cur_digests.get(q_op) == key:
                seed_keys[q_op] = key
        return frontier, seed_keys

    def _try_delta(
        self,
        frontier: ReuseFrontier,
        prev: DataflowDAG,
        prev_plan: Optional[ExecutionPlan],
        plan: ExecutionPlan,
    ):
        """Delta tier: O(|Δrows|) propagation through the changed cone.

        Engages only on a frontier from ``_frontier_seeds`` — i.e. a True
        verdict whose certificate replayed green for the pair — and only
        when the edit is statically amenable (``compute_delta_plan``).
        Returns ``None`` on any fallback condition (not amenable, a table
        evicted mid-chain, a byte-identity precondition violated at run
        time), and the caller takes the seeded reuse run instead — the
        sink bytes are identical either way, only the cost differs.
        """
        if prev_plan is None:
            return None
        from repro.core.frontier import compute_delta_plan
        from repro.engine.delta import DeltaUnsupported, execute_delta

        dplan = compute_delta_plan(frontier, prev, plan.dag)
        if dplan is None:
            return None
        try:
            return execute_delta(
                dplan, prev, plan, prev_plan.digests, self.store
            )
        except DeltaUnsupported:
            return None

    def _decide(
        self,
        prev: DataflowDAG,
        version: DataflowDAG,
        mapping: Optional[EditMapping],
    ):
        """Verify one pair, going through the shared pair-verdict cache
        when one is attached (single-flight: concurrent sessions deciding
        the same content-identical pair run the search exactly once)."""
        def compute():
            verdict, stats, evidence = self.veer.verify_with_evidence(
                prev, version, mapping, semantics=self.semantics
            )
            return verdict, stats, certificate_from_evidence(evidence)

        if self.pair_cache is None:
            verdict, stats, certificate = compute()
            return verdict, stats, certificate, False
        key = self.pair_cache.make_key(prev, version, self.semantics, mapping)
        return self.pair_cache.compute_or_reuse(
            key, compute, pair=(prev, version)
        )

    def report(self) -> ChainReport:
        return self._report

    def save(self) -> None:
        """Persist the verdict cache (no-op for purely in-memory caches)."""
        self.cache.save()

    def close(self) -> None:
        """Persist the cache and release the verifier's window-dispatch
        pool (relevant for ``VeerConfig(max_workers > 1)``); the session
        remains usable — the pool is recreated on the next parallel run."""
        self.save()
        self.veer.close()

    def __enter__(self) -> "VersionChainSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def verify_chain(
    versions: Sequence[DataflowDAG],
    mappings: Optional[Sequence[Optional[EditMapping]]] = None,
    **session_kw,
) -> ChainReport:
    """Batch entry point: verify every consecutive pair of ``versions``.

    ``mappings[k]`` (optional) maps version k to version k+1.
    """
    if mappings is not None and len(mappings) != len(versions) - 1:
        raise ValueError("need exactly one mapping per consecutive pair")
    session = VersionChainSession(**session_kw)
    for k, v in enumerate(versions):
        session.submit(v, mappings[k - 1] if mappings and k > 0 else None)
    session.save()
    return session.report()
