"""Version-chain verification service (paper §1 workload, ROADMAP north star).

Iterative analytics produces *chains* of dataflow versions: v1 → v2 → … → vn,
each a handful of edits from its predecessor.  ``Veer.verify`` answers one
pair; a ``VersionChainSession`` answers the whole chain while amortizing EV
cost across pairs through the canonical-fingerprint verdict cache
(``repro.core.ev.cache``): a window isomorphic to one decided for *any*
earlier pair — or persisted by an earlier session — resolves without an EV
call.  This is the GEqO/EqDAC observation (cache and share semantic
equivalence sub-results) applied to Veer's windowed decomposition search.

Typical use::

    session = VersionChainSession(cache_path="~/.veer/verdicts.json")
    session.submit(v1)                  # first version: nothing to verify
    report = session.submit(v2)         # verifies (v1, v2)
    report = session.submit(v3)         # verifies (v2, v3), reusing verdicts
    print(session.report().summary())
    session.save()                      # persist verdicts for the next session

or, batch-style::

    report = verify_chain([v1, v2, ..., vn])
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import dag as D
from repro.core.dag import DataflowDAG
from repro.core.edits import EditMapping
from repro.core.ev.base import BaseEV
from repro.core.ev.cache import VerdictCache
from repro.core.verifier import Veer, VeerStats, make_veer_plus


def _default_evs() -> List[BaseEV]:
    from repro.core.ev import default_evs

    return default_evs()


@dataclass
class PairReport:
    """Verification outcome for one consecutive pair of the chain."""

    index: int                      # pair k verifies (version k-1, version k)
    verdict: Optional[bool]         # True / False / None (Unknown)
    wall_time: float
    stats: VeerStats

    @property
    def equivalent(self) -> bool:
        return self.verdict is True

    @property
    def ev_calls(self) -> int:
        return self.stats.ev_calls

    @property
    def cache_hits(self) -> int:
        return self.stats.cache_hits

    @property
    def ev_calls_saved(self) -> int:
        return self.stats.ev_calls_saved

    def row(self) -> str:
        v = {True: "EQ", False: "NEQ", None: "UNK"}[self.verdict]
        return (
            f"pair {self.index:>3}: {v:>3}  ev_calls={self.ev_calls:<4} "
            f"cache_hits={self.cache_hits:<4} saved={self.ev_calls_saved:<4} "
            f"{self.wall_time * 1e3:8.1f} ms"
        )


@dataclass
class ChainReport:
    """Aggregate over all pairs verified so far in a session."""

    pairs: List[PairReport] = field(default_factory=list)

    @property
    def total_ev_calls(self) -> int:
        return sum(p.ev_calls for p in self.pairs)

    @property
    def total_cache_hits(self) -> int:
        return sum(p.cache_hits for p in self.pairs)

    @property
    def total_ev_calls_saved(self) -> int:
        return sum(p.ev_calls_saved for p in self.pairs)

    @property
    def total_wall_time(self) -> float:
        return sum(p.wall_time for p in self.pairs)

    @property
    def verdicts(self) -> List[Optional[bool]]:
        return [p.verdict for p in self.pairs]

    def summary(self) -> str:
        lines = [p.row() for p in self.pairs]
        lines.append(
            f"chain: {len(self.pairs)} pairs, "
            f"{self.total_ev_calls} EV calls, "
            f"{self.total_cache_hits} cache hits, "
            f"{self.total_ev_calls_saved} calls saved, "
            f"{self.total_wall_time * 1e3:.1f} ms"
        )
        return "\n".join(lines)


class VersionChainSession:
    """Stateful chain-verification service around a cache-backed ``Veer``.

    Each ``submit`` verifies the new version against the previous one; all
    pairs share one ``VerdictCache`` (optionally persisted at ``cache_path``
    and/or shared with a ``ReuseManager``'s store directory), so pair *k*
    pays EV cost only for windows no earlier pair or session has decided.
    """

    def __init__(
        self,
        evs: Optional[Sequence[BaseEV]] = None,
        *,
        cache: Optional[VerdictCache] = None,
        cache_path: Optional[str] = None,
        semantics: str = D.BAG,
        veer: Optional[Veer] = None,
        **veer_kw,
    ):
        if cache is None:
            cache = VerdictCache(cache_path)
        elif cache_path is not None:
            raise ValueError("pass either cache or cache_path, not both")
        self.cache = cache
        if veer is None:
            veer = make_veer_plus(
                list(evs) if evs is not None else _default_evs(), **veer_kw
            )
        elif evs is not None or veer_kw:
            raise ValueError("pass either veer or evs/veer_kw, not both")
        self.veer = veer.attach_cache(cache)
        self.semantics = semantics
        # only the previous version is needed for the next pair; a long-lived
        # session must not accumulate every DAG it ever saw
        self._prev: Optional[DataflowDAG] = None
        self.version_count = 0
        self._report = ChainReport()

    # -- service API ---------------------------------------------------------
    def submit(
        self,
        version: DataflowDAG,
        mapping: Optional[EditMapping] = None,
    ) -> Optional[PairReport]:
        """Append a version; verify it against the previous one.

        ``mapping`` is the tracked edit mapping from the previous version to
        this one (defaults to the id-stable identity mapping, the natural
        choice when the version-control layer assigns stable operator ids).
        Returns ``None`` for the first version (nothing to verify yet).
        """
        version.validate()
        prev, self._prev = self._prev, version
        self.version_count += 1
        if prev is None:
            return None
        t0 = time.perf_counter()
        verdict, stats = self.veer.verify(
            prev, version, mapping, semantics=self.semantics
        )
        report = PairReport(
            index=self.version_count - 1,
            verdict=verdict,
            wall_time=time.perf_counter() - t0,
            stats=stats,
        )
        self._report.pairs.append(report)
        return report

    def report(self) -> ChainReport:
        return self._report

    def save(self) -> None:
        """Persist the verdict cache (no-op for purely in-memory caches)."""
        self.cache.save()

    def __enter__(self) -> "VersionChainSession":
        return self

    def __exit__(self, *exc) -> None:
        self.save()


def verify_chain(
    versions: Sequence[DataflowDAG],
    mappings: Optional[Sequence[Optional[EditMapping]]] = None,
    **session_kw,
) -> ChainReport:
    """Batch entry point: verify every consecutive pair of ``versions``.

    ``mappings[k]`` (optional) maps version k to version k+1.
    """
    if mappings is not None and len(mappings) != len(versions) - 1:
        raise ValueError("need exactly one mapping per consecutive pair")
    session = VersionChainSession(**session_kw)
    for k, v in enumerate(versions):
        session.submit(v, mappings[k - 1] if mappings and k > 0 else None)
    session.save()
    return session.report()
