"""Process-sharded verification fleet (ISSUE 8, ROADMAP multi-tenant
scale-out).

``VerificationService`` multiplexes clients over *threads* of one process;
the GIL caps it at roughly one core of pure-Python search no matter how
many clients are in flight.  ``VerificationFleet`` is the next rung: N
**worker processes**, each running ordinary serial ``VersionChainSession``s
for the clients sharded onto it, all sharing one second-level cache tier
(``repro.service.remote``) so a pair any worker decided — with its
certificate — is reusable fleet-wide.

Design:

  * **Sharding** — a client is pinned to a worker by consistent hash of
    ``(client_id, first-version content digest)`` over a 64-virtual-node
    ring (sha256-based: Python's ``hash()`` is salted per process and
    can never shard reproducibly).  Chain sessions are stateful (pair k
    needs pair k-1), so the whole chain lives on one worker and runs in
    submission order; different clients land on different workers and run
    genuinely in parallel.
  * **Transport** — one bounded ``multiprocessing.Queue`` per worker
    (backpressure: ``submit`` raises ``ServiceBusy`` when full, same
    contract as the service) and one result queue *per worker* drained by
    a collector thread that resolves the caller's ``Future``s.  Result
    queues are deliberately not shared: a queue has exactly one writing
    process, so a worker killed mid-``put`` (holding the queue's internal
    write lock) can only wedge its own queue — which recovery abandons
    wholesale — never its siblings' ability to report.  Reports
    cross the boundary with the certificate as its canonical JSON (the
    serialization contract — certificates are *evidence*, and the bytes
    the differential suite compares); tables and stats pickle natively.
  * **Recovery** — the parent journals every accepted job per shard.  A
    worker found dead (mid-pair kill, OOM, fault injection) is replaced
    by a fresh process and its shard's journal is replayed from the
    start: chain state is rebuilt deterministically, already-resolved
    futures ignore the duplicate results (same bytes — verification is
    deterministic), unresolved ones get answered.  Verification is
    idempotent, so crash-then-replay can duplicate work but never change
    an answer.
  * **Safety** — workers trust nothing from the shared tier that they
    could not have computed themselves: remote pair hits are served only
    after pair-bound certificate replay, remote tables only after
    content-digest re-verification (see ``repro.service.remote.adapters``
    and docs/SCALE_OUT.md).  The differential suite asserts fleet runs
    are byte-identical to the sequential reference.

``VerificationFleet`` deliberately mirrors the ``VerificationService``
surface that ``workload.replay_sessions`` consumes — ``submit(client_id,
version, mapping, *, sources, block, timeout) -> Future``, ``drain()``,
``close()``, context manager — so the replay driver and its oracles run
unchanged against either backend.
"""

from __future__ import annotations

import bisect
import hashlib
import multiprocessing as mp
import queue as stdlib_queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.api.certificate import Certificate
from repro.api.config import VeerConfig
from repro.api.registry import EVRegistry
from repro.core.dag import DataflowDAG
from repro.core.edits import EditMapping
from repro.service.chain import PairReport, VersionChainSession
from repro.service.remote.adapters import (
    TieredMaterializationStore,
    TieredPairCache,
    TieredVerdictCache,
)
from repro.service.remote.tier import make_tier
from repro.service.server import ServiceBusy, ServiceClosed

#: consecutive respawn failures after which a shard is declared lost and
#: its unresolved futures are failed instead of respawning forever
MAX_RESPAWNS_PER_SHARD = 5

_DRAIN_POLL = 0.05  # parent-side liveness poll while waiting on a barrier


class FleetWorkerLost(RuntimeError):
    """A shard's worker kept dying and its journal could not be replayed."""


# -- consistent hashing -------------------------------------------------------
class ConsistentHashRing:
    """sha256-based ring with virtual nodes.  Deterministic across
    processes and runs (never Python ``hash()``, which is salted), stable
    under small fleets, and uniform enough at 64 vnodes per worker."""

    def __init__(self, n_nodes: int, vnodes: int = 64):
        if n_nodes < 1:
            raise ValueError("need at least one node")
        points: List[Tuple[int, int]] = []
        for node in range(n_nodes):
            for v in range(vnodes):
                h = hashlib.sha256(f"shard-{node}-vnode-{v}".encode()).digest()
                points.append((int.from_bytes(h[:8], "big"), node))
        points.sort()
        self._hashes = [p[0] for p in points]
        self._nodes = [p[1] for p in points]

    def node(self, key: str) -> int:
        h = int.from_bytes(hashlib.sha256(key.encode()).digest()[:8], "big")
        i = bisect.bisect_right(self._hashes, h)
        if i == len(self._hashes):
            i = 0  # wrap: keys past the last point belong to the first
        return self._nodes[i]


def shard_key(client_id: str, first_version: DataflowDAG) -> str:
    """What a client is sharded by: id plus the chain's first content
    digest, so two tenants that happen to share a client name still
    spread, while every later version of one chain maps identically."""
    return f"{client_id}|{first_version.content_digest()}"


# -- wire format --------------------------------------------------------------
def _encode_report(report: Optional[PairReport]) -> Optional[dict]:
    """``PairReport`` → queue-safe dict.  The certificate crosses as its
    canonical JSON; the frontier (an object graph over the worker's DAGs)
    stays behind — nothing parent-side consumes it."""
    if report is None:
        return None
    return {
        "index": report.index,
        "verdict": report.verdict,
        "wall_time": report.wall_time,
        "stats": report.stats,
        "certificate_json": (
            report.certificate.to_json() if report.certificate is not None else None
        ),
        "certified": report.certified,
        "reused": report.reused,
        "exec_stats": report.exec_stats,
        "results": report.results,
    }


def _decode_report(payload: Optional[dict]) -> Optional[PairReport]:
    if payload is None:
        return None
    certificate = None
    if payload["certificate_json"] is not None:
        certificate = Certificate.from_json(payload["certificate_json"])
    report = PairReport(
        index=payload["index"],
        verdict=payload["verdict"],
        wall_time=payload["wall_time"],
        stats=payload["stats"],
        certificate=certificate,
        reused=payload["reused"],
        exec_stats=payload["exec_stats"],
        results=payload["results"],
    )
    report.certified = payload["certified"]  # survives cert-dropping modes
    return report


# -- worker process -----------------------------------------------------------
def _worker_main(worker_id, task_q, result_q, config, registry, keep_certificates):
    """One shard's process: serial chain sessions over tier-backed caches.

    Messages in: ``("job", seq, client_id, version, mapping, sources)``,
    ``("drain", barrier_id)``, ``("stop",)``.  Messages out: ``("ok", wid,
    seq, payload)``, ``("err", wid, seq, repr)``, ``("drained", wid,
    barrier_id, stats)``, ``("stopped", wid)``, ``("fatal", wid, repr)``.
    The task queue is FIFO, so by the time a drain barrier is read every
    prior job of this shard has been answered.
    """
    try:
        tier = make_tier(
            config.shared_tier,
            config.tier_dir,
            ttl_seconds=config.tier_ttl_seconds,
            byte_budget=config.tier_byte_budget,
        )
        cache = TieredVerdictCache(tier, max_entries=config.cache_max_entries)
        pair_cache = TieredPairCache(tier, registry=registry)
        store = TieredMaterializationStore(tier)
        sessions: Dict[str, VersionChainSession] = {}
        while True:
            msg = task_q.get()
            kind = msg[0]
            if kind == "stop":
                result_q.put(("stopped", worker_id))
                return
            if kind == "drain":
                result_q.put(
                    (
                        "drained",
                        worker_id,
                        msg[1],
                        {
                            "cache_stats": cache.stats(),
                            "pair_cache_stats": pair_cache.stats(),
                            "store_stats": store.stats(),
                            "tier_stats": tier.stats(),
                        },
                    )
                )
                continue
            _, seq, client_id, version, mapping, sources = msg
            try:
                session = sessions.get(client_id)
                if session is None:
                    session = VersionChainSession(
                        config=config,
                        registry=registry,
                        cache=cache,
                        keep_certificates=keep_certificates,
                        pair_cache=pair_cache,
                        materialization_store=store,
                    )
                    sessions[client_id] = session
                report = session.submit(version, mapping, sources=sources)
                result_q.put(("ok", worker_id, seq, _encode_report(report)))
            except Exception as e:
                # a failing job answers its future; the worker lives on
                result_q.put(("err", worker_id, seq, repr(e)))
    except BaseException as e:  # tier/config construction, queue teardown
        try:
            result_q.put(("fatal", worker_id, repr(e)))
        except Exception:
            pass
        raise


# -- parent-side bookkeeping --------------------------------------------------
@dataclass
class _JournaledJob:
    seq: int
    client_id: str
    version: DataflowDAG
    mapping: Optional[EditMapping]
    sources: Optional[dict]


@dataclass
class FleetReport:
    """What ``drain`` returns — the subset of ``ServiceReport`` the replay
    driver consumes (errors + cache stats), plus fleet-only accounting."""

    errors: List[str] = field(default_factory=list)
    cache_stats: Dict[str, object] = field(default_factory=dict)
    pair_cache_stats: Dict[str, object] = field(default_factory=dict)
    store_stats: Dict[str, object] = field(default_factory=dict)
    tier_stats: Dict[str, object] = field(default_factory=dict)
    worker_stats: List[Optional[dict]] = field(default_factory=list)
    recoveries: int = 0
    workers: int = 0

    def summary(self) -> str:
        return (
            f"fleet: {self.workers} workers, {self.recoveries} recoveries, "
            f"{len(self.errors)} errors; "
            f"pair tier hits {self.pair_cache_stats.get('tier_hits', 0)}, "
            f"verdict tier hits {self.cache_stats.get('tier_hits', 0)}"
        )


def _merge_numeric(dst: Dict[str, object], src: Dict[str, object]) -> None:
    """Aggregate per-worker stat dicts: sum numbers, keep one exemplar of
    anything non-numeric (backend names, budgets)."""
    for k, v in src.items():
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            dst.setdefault(k, v)
        else:
            cur = dst.get(k, 0)
            dst[k] = (cur if isinstance(cur, (int, float)) else 0) + v


class VerificationFleet:
    """N verification worker processes behind a service-shaped front.

    Parameters mirror ``VerificationService`` where they overlap:
    ``config`` (its ``shared_tier``/``tier_dir`` pick the cache tier every
    worker attaches), ``registry``, ``queue_size`` (per-worker bound;
    backpressure raises ``ServiceBusy``), ``keep_certificates``.
    ``workers`` is the process count — the fleet's parallelism.

    Requires a ``fork`` start method (Linux): workers inherit the config,
    registry, and queue ends directly.  Sessions, caches, and the tier are
    constructed inside each worker, never inherited, so worker state is
    exactly what a fresh single process would build.
    """

    def __init__(
        self,
        workers: int = 2,
        *,
        config: Optional[VeerConfig] = None,
        registry: Optional[EVRegistry] = None,
        queue_size: int = 64,
        keep_certificates: bool = True,
    ):
        if workers < 1:
            raise ValueError("need at least one worker")
        if queue_size < 1:
            raise ValueError("queue_size must be positive")
        self.config = (config if config is not None else VeerConfig()).validate()
        self.registry = registry
        self.keep_certificates = keep_certificates
        self.queue_size = queue_size
        self.n_workers = workers
        self._ctx = mp.get_context("fork")
        self._ring = ConsistentHashRing(workers)
        self._result_qs = [self._ctx.Queue() for _ in range(workers)]
        self._task_qs = [self._ctx.Queue(maxsize=queue_size) for _ in range(workers)]
        self._procs = [self._spawn(i) for i in range(workers)]
        self._lock = threading.Lock()
        self._resolved = threading.Condition(self._lock)
        self._pending: Dict[int, Future] = {}          # seq -> unresolved future
        self._seq = 0
        self._assignments: Dict[str, int] = {}         # client -> shard
        self._journals: List[List[_JournaledJob]] = [[] for _ in range(workers)]
        self._shard_locks = [threading.Lock() for _ in range(workers)]
        self._respawns = [0] * workers
        self._shard_lost: List[Optional[str]] = [None] * workers
        self._errors: List[str] = []
        self._drained: Dict[int, Dict[int, dict]] = {}  # barrier -> wid -> stats
        self._barrier = 0
        self._stopped: set = set()
        self._recoveries = 0
        self._closed = False
        self._collector_stop = threading.Event()
        self._readers = [
            self._start_reader(i, self._result_qs[i]) for i in range(workers)
        ]

    # -- public API ----------------------------------------------------------
    def submit(
        self,
        client_id: str,
        version: DataflowDAG,
        mapping: Optional[EditMapping] = None,
        *,
        sources=None,
        block: bool = True,
        timeout: Optional[float] = None,
    ) -> "Future[Optional[PairReport]]":
        """Enqueue a version for ``client_id``'s chain on its shard.

        Same contract as ``VerificationService.submit``: a Future of the
        pair's ``PairReport`` (None for the first version), strict
        per-client submission order, ``ServiceBusy`` on a full shard queue
        when ``block=False`` (or the timeout lapses)."""
        with self._lock:
            if self._closed:
                raise ServiceClosed("fleet is closed")
            shard = self._assignments.get(client_id)
            if shard is None:
                shard = self._ring.node(shard_key(client_id, version))
                self._assignments[client_id] = shard
            lost = self._shard_lost[shard]
        if lost is not None:
            raise FleetWorkerLost(lost)
        self._ensure_alive(shard)
        future: Future = Future()
        # the shard lock makes (seq allocation, queue insertion, journal
        # append) atomic per shard, so journal order == queue order ==
        # the replay order a replacement worker sees
        with self._shard_locks[shard]:
            with self._lock:
                seq = self._seq
                self._seq += 1
                self._pending[seq] = future
            try:
                self._task_qs[shard].put(
                    ("job", seq, client_id, version, mapping, sources),
                    block=block,
                    timeout=timeout,
                )
            except BaseException as e:
                with self._lock:
                    self._pending.pop(seq, None)
                if isinstance(e, stdlib_queue.Full):
                    raise ServiceBusy("shard queue is full") from None
                raise
            self._journals[shard].append(
                _JournaledJob(seq, client_id, version, mapping, sources)
            )
        return future

    def drain(self) -> FleetReport:
        """Block until every accepted job is answered and every live worker
        has passed a drain barrier; aggregate stats.  Dead workers found on
        the way are replaced and their shard journals replayed — drain
        returns only when the recovered work is answered too."""
        while True:
            barrier = self._post_barrier()
            if self._await_barrier(barrier):
                break
            # a worker died mid-drain: recover (journal replay) and re-run
            # the whole barrier so replacements get their own drain marker
        report = FleetReport(workers=self.n_workers, recoveries=self._recoveries)
        with self._lock:
            report.errors = list(self._errors)
            stats = self._drained.pop(barrier, {})
        report.worker_stats = [stats.get(i) for i in range(self.n_workers)]
        for ws in report.worker_stats:
            if ws is None:
                continue
            _merge_numeric(report.cache_stats, ws["cache_stats"])
            _merge_numeric(report.pair_cache_stats, ws["pair_cache_stats"])
            _merge_numeric(report.store_stats, ws["store_stats"])
            _merge_numeric(report.tier_stats, ws["tier_stats"])
        return report

    def close(self) -> None:
        """Drain, stop the workers, reap the collector.  Idempotent."""
        with self._lock:
            if self._closed:
                return
        try:
            self.drain()
        finally:
            with self._lock:
                self._closed = True
            for i, proc in enumerate(self._procs):
                if proc.is_alive():
                    try:
                        self._task_qs[i].put(("stop",), timeout=5.0)
                    except Exception:
                        pass
            deadline = time.perf_counter() + 10.0
            for proc in self._procs:
                proc.join(timeout=max(0.1, deadline - time.perf_counter()))
                if proc.is_alive():
                    proc.terminate()
                    proc.join(timeout=5.0)
            self._collector_stop.set()
            for t in self._readers:
                t.join(timeout=1.0)  # torn-queue stragglers stay daemonized
            # abandon every queue: a feeder thread left blocked on a pipe
            # whose reader died (killed worker) would otherwise hang
            # interpreter shutdown in multiprocessing's atexit join
            for q in (*self._task_qs, *self._result_qs):
                self._abandon_queue(q)
            with self._lock:
                for fut in self._pending.values():
                    if not fut.done():
                        fut.set_exception(ServiceClosed("fleet closed"))
                self._pending.clear()

    def __enter__(self) -> "VerificationFleet":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- internals ------------------------------------------------------------
    @staticmethod
    def _abandon_queue(q) -> None:
        """Give up on a queue whose peer process is gone: never flush-join
        its feeder at exit (it may be blocked on a dead pipe forever) and
        release its fds.  Data loss is fine — the journal is authoritative."""
        try:
            q.cancel_join_thread()
            q.close()
        except Exception:
            pass

    def _spawn(self, worker_id: int):
        proc = self._ctx.Process(
            target=_worker_main,
            name=f"veer-fleet-{worker_id}",
            args=(
                worker_id,
                self._task_qs[worker_id],
                self._result_qs[worker_id],
                self.config,
                self.registry,
                self.keep_certificates,
            ),
            daemon=True,
        )
        proc.start()
        return proc

    def _start_reader(self, worker_id: int, q) -> threading.Thread:
        t = threading.Thread(
            target=self._read_results,
            args=(q,),
            name=f"veer-fleet-reader-{worker_id}",
            daemon=True,
        )
        t.start()
        return t

    def _read_results(self, q) -> None:
        """One result queue's consumer.  Per-queue threads (never one
        shared loop): a worker killed mid-``put`` leaves a torn message
        that makes any read of *that* queue block forever — here that
        strands only this daemon thread, while recovery swaps in a fresh
        queue with a fresh reader and the journal replay re-produces
        whatever the torn queue still held."""
        while not self._collector_stop.is_set():
            try:
                msg = q.get(timeout=0.2)
            except stdlib_queue.Empty:
                continue
            except Exception:
                return  # queue torn down (close) or corrupt (abandoned)
            self._handle(msg)

    def _handle(self, msg) -> None:
        kind = msg[0]
        if kind == "ok":
            _, _wid, seq, payload = msg
            with self._lock:
                fut = self._pending.pop(seq, None)
                self._resolved.notify_all()
            if fut is not None and not fut.done():
                # decode outside the lock; a replayed duplicate of an
                # already-resolved seq was popped long ago and skipped
                fut.set_result(_decode_report(payload))
        elif kind == "err":
            _, wid, seq, detail = msg
            with self._lock:
                fut = self._pending.pop(seq, None)
                if fut is not None:
                    self._errors.append(f"worker {wid}: {detail}")
                self._resolved.notify_all()
            if fut is not None and not fut.done():
                fut.set_exception(RuntimeError(detail))
        elif kind == "drained":
            _, wid, barrier, stats = msg
            with self._lock:
                self._drained.setdefault(barrier, {})[wid] = stats
                self._resolved.notify_all()
        elif kind == "stopped":
            with self._lock:
                self._stopped.add(msg[1])
                self._resolved.notify_all()
        elif kind == "fatal":
            _, wid, detail = msg
            with self._lock:
                self._errors.append(f"worker {wid} fatal: {detail}")
                self._resolved.notify_all()

    def _post_barrier(self) -> int:
        with self._lock:
            self._barrier += 1
            barrier = self._barrier
        for i in range(self.n_workers):
            if self._shard_lost[i] is None and self._procs[i].is_alive():
                try:
                    self._task_qs[i].put(("drain", barrier), timeout=30.0)
                except Exception:
                    pass  # found dead next poll; barrier re-runs after recovery
        return barrier

    def _await_barrier(self, barrier: int) -> bool:
        """Wait for the barrier on every live shard and all pending futures.
        Returns False if a worker died and was recovered (caller re-runs)."""
        while True:
            with self._lock:
                live = [
                    i for i in range(self.n_workers) if self._shard_lost[i] is None
                ]
                done = self._drained.get(barrier, {})
                if all(i in done for i in live) and not self._pending:
                    return True
                self._resolved.wait(timeout=_DRAIN_POLL)
            recovered = False
            for i in range(self.n_workers):
                if self._shard_lost[i] is None and not self._procs[i].is_alive():
                    self._recover(i)
                    recovered = True
            if recovered:
                return False

    def _ensure_alive(self, shard: int) -> None:
        if not self._procs[shard].is_alive():
            self._recover(shard)

    def _recover(self, shard: int) -> None:
        """Replace a dead worker and replay its journal.  Already-answered
        jobs recompute to rebuild chain state (their duplicate results are
        dropped by the collector); unanswered ones resolve normally."""
        with self._shard_locks[shard]:
            proc = self._procs[shard]
            if proc.is_alive() or self._shard_lost[shard] is not None:
                return  # raced another recoverer, or already written off
            proc.join(timeout=1.0)
            self._respawns[shard] += 1
            with self._lock:
                self._recoveries += 1
            if self._respawns[shard] > MAX_RESPAWNS_PER_SHARD:
                detail = (
                    f"shard {shard} worker died "
                    f"{self._respawns[shard]} times; giving up"
                )
                self._shard_lost[shard] = detail
                self._fail_shard(shard, detail)
                return
            # both of the dead worker's queues are suspect — the task queue
            # may hold undelivered jobs whose feeder is now blocked on a
            # pipe nobody will ever read, and the result queue may be torn
            # mid-``put`` (its internal write lock died held).  Abandon
            # both, start fresh, replay the authoritative journal.
            self._abandon_queue(self._task_qs[shard])
            self._abandon_queue(self._result_qs[shard])
            self._task_qs[shard] = self._ctx.Queue(maxsize=self.queue_size)
            fresh_q = self._ctx.Queue()
            with self._lock:
                self._result_qs[shard] = fresh_q
            self._readers.append(self._start_reader(shard, fresh_q))
            self._procs[shard] = self._spawn(shard)
            for job in self._journals[shard]:
                try:
                    self._task_qs[shard].put(
                        ("job", job.seq, job.client_id, job.version,
                         job.mapping, job.sources),
                        timeout=60.0,
                    )
                except stdlib_queue.Full:
                    # the replacement died already (its next liveness poll
                    # triggers another recovery against a fresh queue, which
                    # replays the whole journal again) — stop pushing here
                    break

    def _fail_shard(self, shard: int, detail: str) -> None:
        journal_seqs = {j.seq for j in self._journals[shard]}
        with self._lock:
            self._errors.append(detail)
            for seq in list(self._pending):
                if seq in journal_seqs:
                    fut = self._pending.pop(seq)
                    if not fut.done():
                        fut.set_exception(FleetWorkerLost(detail))
            self._resolved.notify_all()
