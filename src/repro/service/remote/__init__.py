"""Shared remote tier for scale-out: the ``SharedTier`` protocol, its
local/file backends, and the cache adapters that graft a tier onto the
in-process ``VerdictCache``/``PairVerdictCache``/``MaterializationStore``
(see ``docs/SCALE_OUT.md``)."""

from repro.service.remote.adapters import (
    TieredMaterializationStore,
    TieredPairCache,
    TieredVerdictCache,
)
from repro.service.remote.filetier import FileLease, FileTier
from repro.service.remote.tier import (
    Lease,
    LocalTier,
    PairRecord,
    SharedTier,
    make_tier,
)

__all__ = [
    "FileLease",
    "FileTier",
    "Lease",
    "LocalTier",
    "PairRecord",
    "SharedTier",
    "TieredMaterializationStore",
    "TieredPairCache",
    "TieredVerdictCache",
    "make_tier",
]
