"""Tier-backed drop-ins for the three in-process caches.

Each adapter subclasses the cache it replaces and adds the shared tier as
a read-through/write-through second level: local state stays the hot path
(same locks, same LRU bounds, same counters), the tier only sees local
misses and publishes.  Construction is the only difference callers ever
observe — every call site keeps the base-class API.

The trust rules (docs/SCALE_OUT.md §safety):

  * **pair verdicts** — a hit from an *untrusted* tier (``FileTier``) is
    served only after its certificate replays green **bound to the pair**
    (``Certificate.replay(registry, P, Q)``: digest match, fingerprints
    re-derived from the pair, coverage re-checked).  A record with no
    certificate, a failed replay, or a verdict disagreeing with its own
    certificate is a counted miss and the pair is recomputed.
  * **tables** — ``FileTier.get_table`` re-hashes every payload against
    its content address before returning it, so the adapter can promote
    whatever the tier hands back.
  * **window verdicts/validity** — replayed as-is from either tier: the
    persisted ``VerdictCache`` snapshot already carries exactly this trust
    level (a JSON file on disk loaded without re-checking), and the
    fingerprint keying plus EV determinism make a *well-formed* entry
    correct by construction; a malformed one is unlinked and counted by
    the tier before it ever reaches the adapter.

Cross-process single-flight lives in ``TieredPairCache``: the local
``acquire`` coalesces threads of this process, then the owner takes the
tier lease for the pair before computing.  If another process holds it,
the owner waits (bounded); when the lease turns over it re-checks the
tier — the usual outcome is that the other process published and the
search never runs here.  Lease-wait timeout or a dead former holder (the
kernel drops its flock) degrade to duplicate computation, never to a
wrong or missing result.
"""

from __future__ import annotations

import hashlib
import json
import threading
from typing import Callable, Dict, Optional, Tuple

from repro.api.certificate import Certificate
from repro.core.dag import DataflowDAG
from repro.api.registry import EVRegistry
from repro.core.ev.cache import CacheEntry, VerdictCache
from repro.core.verifier import VeerStats
from repro.engine.store import InMemoryMaterializationStore
from repro.engine.table import Table
from repro.service.pair_cache import PairEntry, PairKey, PairVerdictCache
from repro.service.remote.tier import PairRecord, SharedTier

#: how long a pair owner waits on another process's lease before giving up
#: and computing anyway (correct either way — just duplicated work)
LEASE_WAIT_SECONDS = 30.0


class TieredVerdictCache(VerdictCache):
    """``VerdictCache`` with a shared second level for verdicts+validity."""

    def __init__(
        self,
        tier: SharedTier,
        path: Optional[str] = None,
        *,
        autoload: bool = True,
        max_entries: Optional[int] = None,
    ):
        self.tier = tier  # before super().__init__: autoload may call load()
        self.tier_hits = 0
        super().__init__(path, autoload=autoload, max_entries=max_entries)

    def get(self, ev_name: str, fingerprint: str) -> Optional[CacheEntry]:
        entry = super().get(ev_name, fingerprint)
        if entry is not None:
            return entry
        got = self.tier.get_verdict(ev_name, fingerprint)
        if got is None:
            return None
        verdict, elapsed = got
        entry = CacheEntry(verdict, elapsed)
        # promote locally without writing back to the tier (super(), not
        # self: the entry is already there)
        super().put(ev_name, fingerprint, verdict, elapsed)
        with self._lock:
            self.tier_hits += 1
            self.time_saved += elapsed
        return entry

    def put(self, ev_name, fingerprint, verdict, elapsed) -> None:
        super().put(ev_name, fingerprint, verdict, elapsed)
        self.tier.put_verdict(ev_name, fingerprint, verdict, elapsed)

    def get_validity(self, ev_name: str, fingerprint: str) -> Optional[bool]:
        ok = super().get_validity(ev_name, fingerprint)
        if ok is not None:
            return ok
        ok = self.tier.get_validity(ev_name, fingerprint)
        if ok is None:
            return None
        super().put_validity(ev_name, fingerprint, ok)
        with self._lock:
            self.tier_hits += 1
        return ok

    def put_validity(self, ev_name: str, fingerprint: str, valid: bool) -> None:
        super().put_validity(ev_name, fingerprint, valid)
        self.tier.put_validity(ev_name, fingerprint, valid)

    def stats(self) -> Dict[str, object]:
        out = super().stats()
        with self._lock:
            out["tier_hits"] = self.tier_hits
        return out


def _tier_pair_key(key: PairKey) -> str:
    """Stable string form of a ``PairKey`` for tier storage (tuples become
    JSON lists; deterministic across processes, unlike ``repr`` of nested
    structures is not — and unlike ``hash()``, which is salted)."""
    digest, raw, mapping = key
    return json.dumps(
        [
            digest,
            None if raw is None else list(raw),
            None if mapping is None else [list(e) for e in mapping],
        ],
        separators=(",", ":"),
    )


class TieredPairCache(PairVerdictCache):
    """``PairVerdictCache`` with a shared second level and cross-process
    single-flight.  Pair hits that crossed a process boundary are gated by
    pair-bound certificate replay (see module docstring)."""

    def __init__(
        self,
        tier: SharedTier,
        *,
        registry: Optional[EVRegistry] = None,
        max_entries: int = 65_536,
        lease_wait: float = LEASE_WAIT_SECONDS,
    ):
        super().__init__(max_entries=max_entries)
        self.tier = tier
        self.registry = registry
        self.lease_wait = lease_wait
        self._tier_lock = threading.Lock()
        self.tier_hits = 0
        self.tier_replay_rejections = 0
        self.lease_waits = 0

    def compute_or_reuse(
        self,
        key: PairKey,
        compute: Callable,
        *,
        pair: Optional[Tuple[DataflowDAG, DataflowDAG]] = None,
    ):
        entry, _owner = self.acquire(key)
        if entry is not None:
            return self._reuse(entry)
        # this thread owns the local flight; before paying for the search,
        # consult the shared tier, holding the cross-process lease so at
        # most one process fleet-wide computes this pair
        tkey = _tier_pair_key(key)
        entry = self._tier_fetch(tkey, pair)
        if entry is not None:
            self.publish(key, entry)
            return self._reuse(entry)
        lease = self.tier.lease(f"pair:{_lease_name(tkey)}")
        held = lease.acquire(block=False)
        if not held:
            with self._tier_lock:
                self.lease_waits += 1
            held = lease.wait(self.lease_wait)
            # the previous holder resolved (or died): its published result
            # is in the tier now if it ever will be
            entry = self._tier_fetch(tkey, pair)
            if entry is not None:
                if held:
                    lease.release()
                self.publish(key, entry)
                return self._reuse(entry)
        try:
            verdict, stats, certificate = compute()
        except BaseException:
            self.abandon(key)
            if held:
                lease.release()
            raise
        if verdict is None:
            self.abandon(key)  # Unknown: never cached, locally or remotely
        else:
            entry = PairEntry(
                verdict=verdict,
                certificate=certificate,
                ev_calls_avoided=stats.ev_calls + stats.ev_calls_saved,
                ev_time_avoided=stats.ev_time + stats.ev_time_saved,
            )
            self.publish(key, entry)
            self.tier.put_pair(
                tkey,
                PairRecord(
                    verdict=verdict,
                    certificate_json=(
                        certificate.to_json() if certificate is not None else None
                    ),
                    ev_calls_avoided=entry.ev_calls_avoided,
                    ev_time_avoided=entry.ev_time_avoided,
                ),
            )
        if held:
            lease.release()
        return verdict, stats, certificate, False

    # -- internals ------------------------------------------------------------
    def _reuse(self, entry: PairEntry):
        stats = VeerStats(
            verdict=entry.verdict,
            ev_calls_saved=entry.ev_calls_avoided,
            ev_time_saved=entry.ev_time_avoided,
        )
        return entry.verdict, stats, entry.certificate, True

    def _tier_fetch(
        self,
        tkey: str,
        pair: Optional[Tuple[DataflowDAG, DataflowDAG]],
    ) -> Optional[PairEntry]:
        """Tier lookup + the trust gate.  Returns a servable ``PairEntry``
        or None (miss, damaged record, or failed replay — recompute)."""
        record = self.tier.get_pair(tkey)
        if record is None:
            return None
        certificate: Optional[Certificate] = None
        if record.certificate_json is not None:
            try:
                certificate = Certificate.from_json(record.certificate_json)
            except Exception:
                certificate = None
        if not self.tier.trusted:
            # remote entries are evidence, not answers: require a
            # certificate, require it to agree with the stored verdict, and
            # require a green replay *bound to this pair*
            if (
                certificate is None
                or pair is None
                or certificate.verdict is not record.verdict
            ):
                self._reject()
                return None
            try:
                report = certificate.replay(self.registry, pair[0], pair[1])
            except Exception:
                report = None
            if report is None or not report.ok:
                self._reject()
                return None
        with self._tier_lock:
            self.tier_hits += 1
        return PairEntry(
            verdict=record.verdict,
            certificate=certificate,
            ev_calls_avoided=record.ev_calls_avoided,
            ev_time_avoided=record.ev_time_avoided,
        )

    def _reject(self) -> None:
        with self._tier_lock:
            self.tier_replay_rejections += 1

    def stats(self) -> Dict[str, object]:
        out = super().stats()
        with self._tier_lock:
            out["tier_hits"] = self.tier_hits
            out["tier_replay_rejections"] = self.tier_replay_rejections
            out["lease_waits"] = self.lease_waits
        return out


def _lease_name(tkey: str) -> str:
    return hashlib.sha256(tkey.encode()).hexdigest()[:32]


class TieredMaterializationStore(InMemoryMaterializationStore):
    """In-memory store with the tier as a shared second level.

    ``get`` promotes tier hits into local memory; ``put`` writes through.
    Local eviction releases only the local copy; the tier keeps its own
    refcounts and budget.  Digest safety is the tier's job (``FileTier``
    re-hashes payloads on read), so promotion needs no extra checks here.
    """

    def __init__(self, tier: SharedTier, byte_budget: Optional[int] = None):
        super().__init__(byte_budget)
        self.tier = tier
        self.tier_hits = 0

    def get(self, key: str) -> Optional[Table]:
        table = super().get(key)
        if table is not None:
            return table
        got = self.tier.get_table(key)
        if got is None:
            return None
        table, elapsed = got
        super().put(key, table, elapsed)
        with self._lock:
            self.tier_hits += 1
            self.time_saved += elapsed
        return table

    def put(self, key: str, table: Table, elapsed: float = 0.0) -> bool:
        fresh = super().put(key, table, elapsed)
        self.tier.put_table(key, table, elapsed)
        return fresh

    def stats(self) -> Dict[str, object]:
        out = super().stats()
        with self._lock:
            out["tier_hits"] = self.tier_hits
        return out
