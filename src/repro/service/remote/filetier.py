"""``FileTier`` — the shared remote materialization/verdict tier.

One directory, shared by every worker process of a ``VerificationFleet``
(same box or same network filesystem), holding the three namespaces the
cache adapters read/write through plus the lease files that give
cross-process single-flight:

``tier.lock``                    global index lock (``fcntl.flock``)
``verdicts/<h>.json``            window verdict: ``{"k": [ev, fp], "v": "T|F|U", "s": secs}``
``validity/<h>.json``            restriction check: ``{"k": [ev, fp], "ok": bool}``
``pairs/<h>.json``               pair verdict + certificate JSON
``tables/<h>.json``              materialization key → payload ref
``objects/<tdigest>.npz``        content-addressed table payload (+ ``.meta.json``)
``objects/<tdigest>.refs``       payload reference count: ``{"count": n}``
``leases/<h>.lock``              single-flight leases (kernel-released on death)

Hardening, in the ``VerdictCache``/``DiskMaterializationStore`` tradition
(the fault-injection suite ``tests/test_fleet_faults.py`` drives every
branch):

  * every write is temp-file + ``os.replace`` — a reader or a crash
    mid-write sees the old entry or the new one, never a torn half;
  * every entry embeds the key it serves (``"k"``); a read whose payload
    is truncated, malformed, or keyed differently is **counted and
    treated as a miss** (the damaged file is unlinked), never returned;
  * table payloads are verified against their content address on every
    read — ``table_digest(loaded) == tdigest`` or the entry reads as a
    counted miss.  A remote tier can therefore *lose* work but never
    serve wrong bytes;
  * entries expire after ``ttl_seconds`` (mtime-based, checked on read
    and on ``sweep()``); object bytes are bounded by ``byte_budget`` with
    stalest-key-first eviction;
  * payloads are refcounted by the key entries naming them, and a payload
    is only ever garbage-collected when its refcount reaches zero **and**
    a scan of the key namespace confirms no live key still references it
    — so a stale refcount file or a double ``release_table`` can never
    free a live materialization;
  * leases are ``fcntl.flock`` locks: exactly one process holds one at a
    time, and the kernel releases the lock when the holder dies, so a
    worker crashing mid-compute never wedges its waiters.

Concurrency model: index mutations (refcounts, evictions, key writes)
serialize on the single global ``tier.lock``; reads go lock-free against
atomically-replaced files.  Coarse, but correct — and the tier is a
*second* level behind each worker's in-process caches, so it sees misses
and publishes, not the hot path.
"""

from __future__ import annotations

import fcntl
import hashlib
import json
import os
import pathlib
import threading
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.engine.store import _atomic_write, _jsonable, table_digest
from repro.engine.table import Table
from repro.service.remote.tier import Lease, PairRecord

_VERDICT_TO_JSON = {True: "T", False: "F", None: "U"}
_VERDICT_FROM_JSON = {v: k for k, v in _VERDICT_TO_JSON.items()}


def _hname(*parts: str) -> str:
    """Filesystem-safe entry name for an arbitrary key tuple."""
    return hashlib.sha256("\x1f".join(parts).encode()).hexdigest()[:40]


class _GlobalLock:
    """``with`` wrapper over ``fcntl.flock`` on the tier's lock file.

    A fresh fd per acquisition: flock excludes across *open file
    descriptions*, so this serializes both other processes and other
    threads of this process."""

    def __init__(self, path: pathlib.Path):
        self.path = path
        self._fd: Optional[int] = None

    def __enter__(self) -> "_GlobalLock":
        self._fd = os.open(self.path, os.O_CREAT | os.O_RDWR, 0o644)
        fcntl.flock(self._fd, fcntl.LOCK_EX)
        return self

    def __exit__(self, *exc) -> None:
        if self._fd is not None:
            fcntl.flock(self._fd, fcntl.LOCK_UN)
            os.close(self._fd)
            self._fd = None


class FileLease(Lease):
    """Cross-process lease: ``flock`` on a dedicated file.

    ``acquire`` is try-lock (or bounded blocking via ``wait``'s polling,
    inherited); ``release`` is idempotent; death of the holding process
    releases the underlying lock automatically."""

    def __init__(self, path: pathlib.Path):
        self.path = path
        self._fd: Optional[int] = None

    def acquire(self, block: bool = False, timeout: float = 0.0) -> bool:
        if self._fd is not None:
            return True
        fd = os.open(self.path, os.O_CREAT | os.O_RDWR, 0o644)
        if block:
            if not self._flock_deadline(fd, timeout):
                os.close(fd)
                return False
        else:
            try:
                fcntl.flock(fd, fcntl.LOCK_EX | fcntl.LOCK_NB)
            except OSError:
                os.close(fd)
                return False
        self._fd = fd
        return True

    @staticmethod
    def _flock_deadline(fd: int, timeout: float, poll: float = 0.02) -> bool:
        deadline = time.perf_counter() + timeout
        while True:
            try:
                fcntl.flock(fd, fcntl.LOCK_EX | fcntl.LOCK_NB)
                return True
            except OSError:
                if time.perf_counter() >= deadline:
                    return False
                time.sleep(poll)

    def release(self) -> None:
        if self._fd is not None:
            try:
                fcntl.flock(self._fd, fcntl.LOCK_UN)
            finally:
                os.close(self._fd)
                self._fd = None


class FileTier:
    """Shared-directory ``SharedTier`` backend (see module docstring)."""

    trusted = False  # cross-process entries: pair hits must replay their cert

    def __init__(
        self,
        directory: str,
        *,
        ttl_seconds: Optional[float] = None,
        byte_budget: Optional[int] = None,
    ):
        if ttl_seconds is not None and ttl_seconds <= 0:
            raise ValueError(f"ttl_seconds must be positive, got {ttl_seconds}")
        if byte_budget is not None and byte_budget <= 0:
            raise ValueError(f"byte_budget must be positive, got {byte_budget}")
        self.dir = pathlib.Path(directory).expanduser()
        self.ttl_seconds = ttl_seconds
        self.byte_budget = byte_budget
        for sub in ("verdicts", "validity", "pairs", "tables", "objects", "leases"):
            (self.dir / sub).mkdir(parents=True, exist_ok=True)
        self._lockfile = self.dir / "tier.lock"
        self._stats_lock = threading.Lock()  # counters only
        self.hits = 0
        self.misses = 0
        self.corrupt_entries_skipped = 0
        self.expired_entries = 0
        self.evictions = 0
        self.digest_rejections = 0

    # -- counters -------------------------------------------------------------
    def _bump(self, name: str, by: int = 1) -> None:
        with self._stats_lock:
            setattr(self, name, getattr(self, name) + by)

    # -- generic JSON entries -------------------------------------------------
    def _entry_path(self, namespace: str, *key: str) -> pathlib.Path:
        return self.dir / namespace / f"{_hname(*key)}.json"

    def _read_entry(self, namespace: str, *key: str) -> Optional[dict]:
        """Read one entry, enforcing TTL and the embedded-key self-check.
        Anything damaged is unlinked and counted — a miss, never a raise."""
        path = self._entry_path(namespace, *key)
        try:
            if self._expired(path):
                self._bump("expired_entries")
                self._bump("misses")
                self._unlink(path)
                return None
            rec = json.loads(path.read_text())
            if not isinstance(rec, dict) or rec.get("k") != list(key):
                raise ValueError("key self-check failed")
        except FileNotFoundError:
            self._bump("misses")
            return None
        except (OSError, json.JSONDecodeError, ValueError, TypeError):
            self._bump("corrupt_entries_skipped")
            self._bump("misses")
            self._unlink(path)
            return None
        self._bump("hits")
        return rec

    def _write_entry(self, namespace: str, key: Tuple[str, ...], payload: dict) -> None:
        payload = {"k": list(key), **payload}
        _atomic_write(
            self._entry_path(namespace, *key),
            lambda f: f.write(json.dumps(payload)),
        )

    def _expired(self, path: pathlib.Path) -> bool:
        if self.ttl_seconds is None:
            return False
        try:
            return (time.time() - path.stat().st_mtime) > self.ttl_seconds
        except OSError:
            return False  # vanished: the read path reports the plain miss

    @staticmethod
    def _unlink(path: pathlib.Path) -> None:
        try:
            path.unlink()
        except OSError:
            pass

    # -- window verdicts ------------------------------------------------------
    def get_verdict(self, ev_name, fingerprint):
        rec = self._read_entry("verdicts", ev_name, fingerprint)
        if rec is None:
            return None
        try:
            return _VERDICT_FROM_JSON[rec["v"]], float(rec["s"])
        except (KeyError, TypeError, ValueError):
            self._bump("corrupt_entries_skipped")
            self._unlink(self._entry_path("verdicts", ev_name, fingerprint))
            return None

    def put_verdict(self, ev_name, fingerprint, verdict, elapsed):
        self._write_entry(
            "verdicts",
            (ev_name, fingerprint),
            {"v": _VERDICT_TO_JSON[verdict], "s": round(float(elapsed), 6)},
        )

    def get_validity(self, ev_name, fingerprint):
        rec = self._read_entry("validity", ev_name, fingerprint)
        if rec is None or not isinstance(rec.get("ok"), bool):
            return None
        return rec["ok"]

    def put_validity(self, ev_name, fingerprint, valid):
        self._write_entry("validity", (ev_name, fingerprint), {"ok": bool(valid)})

    # -- pairs ----------------------------------------------------------------
    def get_pair(self, key: str) -> Optional[PairRecord]:
        rec = self._read_entry("pairs", key)
        if rec is None:
            return None
        try:
            cert = rec["cert"]
            if cert is not None and not isinstance(cert, str):
                raise TypeError("cert must be a JSON string")
            return PairRecord(
                verdict=bool(rec["verdict"]),
                certificate_json=cert,
                ev_calls_avoided=int(rec["calls"]),
                ev_time_avoided=float(rec["time"]),
            )
        except (KeyError, TypeError, ValueError):
            self._bump("corrupt_entries_skipped")
            self._unlink(self._entry_path("pairs", key))
            return None

    def put_pair(self, key: str, record: PairRecord) -> None:
        self._write_entry(
            "pairs",
            (key,),
            {
                "verdict": record.verdict,
                "cert": record.certificate_json,
                "calls": record.ev_calls_avoided,
                "time": round(record.ev_time_avoided, 6),
            },
        )

    # -- tables ---------------------------------------------------------------
    def get_table(self, key: str) -> Optional[Tuple[Table, float]]:
        rec = self._read_entry("tables", key)
        if rec is None:
            return None
        try:
            tdigest, elapsed = str(rec["table"]), float(rec["elapsed"])
        except (KeyError, TypeError, ValueError):
            self._bump("corrupt_entries_skipped")
            self._unlink(self._entry_path("tables", key))
            return None
        table = self._read_payload(tdigest)
        if table is None or table_digest(table) != tdigest:
            # truncated npz, malformed meta, or valid-looking bytes that do
            # not hash to their content address: never serve them
            self._bump("digest_rejections" if table is not None else
                       "corrupt_entries_skipped")
            with _GlobalLock(self._lockfile):
                self._release_table_locked(key)
                self._drop_payload(tdigest)  # unreadable/forged: rewritable
            return None
        return table, elapsed

    def put_table(self, key: str, table: Table, elapsed: float = 0.0) -> None:
        tdigest = table_digest(table)
        with _GlobalLock(self._lockfile):
            old = self._peek_table_ref(key)
            if not (self.dir / "objects" / f"{tdigest}.npz").exists():
                self._write_payload(tdigest, table)
            if old != tdigest:
                self._bump_refcount(tdigest, +1)
                if old is not None:
                    self._decref_and_maybe_gc(old, skip_key=key)
            self._write_entry(
                "tables", (key,),
                {"table": tdigest, "elapsed": round(float(elapsed), 6)},
            )
            self._enforce_byte_budget(protect=key)

    def release_table(self, key: str) -> None:
        """Drop one key's reference; GC the payload only when no live key
        still names it.  Releasing an absent key is a no-op — double
        releases can never drive a refcount past its true value."""
        with _GlobalLock(self._lockfile):
            self._release_table_locked(key)

    # -- table internals (caller holds the global lock) -----------------------
    def _peek_table_ref(self, key: str) -> Optional[str]:
        path = self._entry_path("tables", key)
        try:
            rec = json.loads(path.read_text())
            return str(rec["table"])
        except (OSError, json.JSONDecodeError, KeyError, TypeError, ValueError):
            return None

    def _release_table_locked(self, key: str) -> None:
        tdigest = self._peek_table_ref(key)
        self._unlink(self._entry_path("tables", key))
        if tdigest is not None:
            self._decref_and_maybe_gc(tdigest)

    def _refs_path(self, tdigest: str) -> pathlib.Path:
        return self.dir / "objects" / f"{tdigest}.refs"

    def _read_refcount(self, tdigest: str) -> int:
        try:
            return max(0, int(json.loads(self._refs_path(tdigest).read_text())["count"]))
        except (OSError, json.JSONDecodeError, KeyError, TypeError, ValueError):
            return 0  # missing/corrupt refcount: rebuilt by the live scan

    def _bump_refcount(self, tdigest: str, by: int) -> None:
        count = max(0, self._read_refcount(tdigest) + by)
        _atomic_write(
            self._refs_path(tdigest), lambda f: f.write(json.dumps({"count": count}))
        )

    def _live_references(self, tdigest: str) -> int:
        """Authoritative reference count: scan the key namespace.  This is
        the guard that makes stale refcounts and double releases harmless
        — a payload is freed only when *no key file* names it."""
        live = 0
        for p in (self.dir / "tables").glob("*.json"):
            try:
                if json.loads(p.read_text()).get("table") == tdigest:
                    live += 1
            except (OSError, json.JSONDecodeError, ValueError):
                continue
        return live

    def _decref_and_maybe_gc(self, tdigest: str, skip_key: Optional[str] = None) -> None:
        self._bump_refcount(tdigest, -1)
        if self._read_refcount(tdigest) <= 0:
            if self._live_references(tdigest) == 0:
                self._drop_payload(tdigest)
            else:
                # stale refcount (crash between key write and refs write, or
                # a corrupted refs file): resync to the live scan, keep it
                _atomic_write(
                    self._refs_path(tdigest),
                    lambda f: json.dump(
                        {"count": self._live_references(tdigest)}, f
                    ),
                )

    def _drop_payload(self, tdigest: str) -> None:
        for suffix in (".npz", ".meta.json", ".refs"):
            self._unlink(self.dir / "objects" / f"{tdigest}{suffix}")

    def _write_payload(self, tdigest: str, table: Table) -> None:
        payload, meta = {}, {"order": table.order, "object_cols": []}
        for c in table.order:
            arr = table.cols[c]
            if arr.dtype == object:
                meta["object_cols"].append(c)
                payload[c] = np.array([json.dumps(_jsonable(v)) for v in arr])
            else:
                payload[c] = arr
        _atomic_write(
            self.dir / "objects" / f"{tdigest}.npz",
            lambda f: np.savez(f, **payload),
            binary=True,
        )
        _atomic_write(
            self.dir / "objects" / f"{tdigest}.meta.json",
            lambda f: f.write(json.dumps(meta)),
        )

    def _read_payload(self, tdigest: str) -> Optional[Table]:
        try:
            meta = json.loads(
                (self.dir / "objects" / f"{tdigest}.meta.json").read_text()
            )
            with np.load(
                self.dir / "objects" / f"{tdigest}.npz", allow_pickle=False
            ) as data:
                cols = {}
                for c in meta["order"]:
                    arr = data[c]
                    if c in meta["object_cols"]:
                        arr = np.array([json.loads(s) for s in arr], dtype=object)
                    cols[c] = arr
            return Table(cols, meta["order"])
        except Exception:
            return None  # damaged payload reads as a miss, never a raise

    # -- eviction -------------------------------------------------------------
    def _object_bytes(self) -> int:
        total = 0
        for p in (self.dir / "objects").glob("*.npz"):
            try:
                total += p.stat().st_size
            except OSError:
                continue
        return total

    def _enforce_byte_budget(self, protect: Optional[str] = None) -> None:
        """Stalest-key-first eviction until object bytes fit the budget
        (caller holds the global lock).  The just-written ``protect`` key
        survives even when a single table exceeds the whole budget."""
        if self.byte_budget is None:
            return
        while self._object_bytes() > self.byte_budget:
            candidates = []
            for p in (self.dir / "tables").glob("*.json"):
                try:
                    rec = json.loads(p.read_text())
                    key = rec["k"][0]
                except (OSError, json.JSONDecodeError, KeyError,
                        IndexError, TypeError):
                    self._unlink(p)  # unreadable ref: drop, payload GCs below
                    continue
                if key == protect:
                    continue
                candidates.append((p.stat().st_mtime, key))
            if not candidates:
                # nothing left to evict but orphaned payloads may remain
                self._gc_orphan_payloads(protect)
                return
            candidates.sort()
            self._release_table_locked(candidates[0][1])
            self._bump("evictions")

    def _gc_orphan_payloads(self, protect: Optional[str] = None) -> None:
        protected = self._peek_table_ref(protect) if protect else None
        for p in (self.dir / "objects").glob("*.npz"):
            tdigest = p.stem
            if tdigest == protected:
                continue
            if self._live_references(tdigest) == 0:
                self._drop_payload(tdigest)

    def sweep(self) -> Dict[str, int]:
        """Expire TTL-stale entries and re-enforce the byte budget; returns
        what was dropped.  Cheap enough to run opportunistically (the
        fleet runs it at drain)."""
        dropped = {"expired": 0, "evicted_before": self.evictions}
        if self.ttl_seconds is not None:
            for namespace in ("verdicts", "validity", "pairs"):
                for p in (self.dir / namespace).glob("*.json"):
                    if self._expired(p):
                        self._unlink(p)
                        dropped["expired"] += 1
            with _GlobalLock(self._lockfile):
                for p in (self.dir / "tables").glob("*.json"):
                    if self._expired(p):
                        try:
                            key = json.loads(p.read_text())["k"][0]
                        except (OSError, json.JSONDecodeError, KeyError,
                                IndexError, TypeError):
                            self._unlink(p)
                            continue
                        self._release_table_locked(key)
                        dropped["expired"] += 1
        with _GlobalLock(self._lockfile):
            self._enforce_byte_budget()
            self._gc_orphan_payloads()
        dropped["evicted"] = self.evictions - dropped.pop("evicted_before")
        self._bump("expired_entries", dropped["expired"])
        return dropped

    # -- leases ---------------------------------------------------------------
    def lease(self, name: str) -> FileLease:
        return FileLease(self.dir / "leases" / f"{_hname(name)}.lock")

    # -- stats ----------------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        with self._stats_lock:
            return {
                "backend": "remote",
                "dir": str(self.dir),
                "ttl_seconds": self.ttl_seconds,
                "byte_budget": self.byte_budget,
                "object_bytes": self._object_bytes(),
                "hits": self.hits,
                "misses": self.misses,
                "corrupt_entries_skipped": self.corrupt_entries_skipped,
                "expired_entries": self.expired_entries,
                "digest_rejections": self.digest_rejections,
                "evictions": self.evictions,
            }
