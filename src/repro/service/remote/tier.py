"""``SharedTier`` — the pluggable storage protocol behind the three caches.

A single verification process keeps three in-memory maps hot: window
verdicts (``VerdictCache``), whole-pair verdicts with their certificates
(``PairVerdictCache``), and operator materializations
(``MaterializationStore``).  Scaling past one process (ISSUE 8, ROADMAP
"Multi-tenant scale-out") means those maps must be *shareable* across
worker processes without weakening any of the digest guards that make
reuse sound.  ``SharedTier`` is the seam: the in-process caches stay
exactly as they are and gain a read-through/write-through second level
(``repro.service.remote.adapters``), and the tier decides where that
level lives:

  * ``LocalTier`` — plain in-process dicts under a lock.  This is today's
    behavior restated behind the protocol: nothing crosses a process
    boundary, entries are trusted because this process wrote them.
  * ``FileTier`` (``repro.service.remote.filetier``) — a shared directory
    with fcntl-locked, content-addressed, refcounted, TTL/byte-budget
    evicted entries, usable by every worker process of a
    ``VerificationFleet`` at once.

The ``trusted`` flag is the load-bearing difference: a trusted tier's
pair entries may be served as-is (same trust as the in-memory dict they
replace), while an untrusted tier's pair hits must first pass a
pair-bound certificate replay (see ``adapters.TieredPairCache``) — a
remote verdict is *evidence to re-check*, never an answer to believe.

Leases give cross-process single-flight: ``lease(name)`` returns a
``Lease`` whose ``acquire(block=False)`` succeeds for exactly one holder
at a time; with ``FileTier`` the lock is an ``fcntl.flock`` the kernel
releases when the holder dies, so a crashed owner can never wedge the
other workers.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Dict, Optional, Protocol, Tuple, runtime_checkable

from repro.engine.table import Table


@dataclass(frozen=True)
class PairRecord:
    """One decided pair as a tier stores it: the verdict, the certificate
    JSON (the serialization contract — never pickled objects), and the
    cost the original run paid so hits can account the work avoided."""

    verdict: bool
    certificate_json: Optional[str]
    ev_calls_avoided: int
    ev_time_avoided: float


class Lease:
    """In-process lease: a non-reentrant try-lock with polling ``wait``.

    ``FileTier`` subclasses this with an fcntl-backed variant; both share
    the contract that at most one holder has ``acquire`` succeed at a
    time, and that ``release`` is idempotent (double-release is a no-op —
    the fault-injection suite leans on this).
    """

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self._held = False

    def acquire(self, block: bool = False, timeout: float = 0.0) -> bool:
        if self._held:
            return True
        if block:
            self._held = self._lock.acquire(timeout=max(timeout, 0.0))
        else:
            self._held = self._lock.acquire(blocking=False)
        return self._held

    def wait(self, timeout: float, poll: float = 0.02) -> bool:
        """Poll-acquire until the current holder releases (or ``timeout``).
        Returns True iff the lease was acquired — the caller is then the
        new holder and must ``release``."""
        deadline = time.perf_counter() + timeout
        while not self.acquire(block=False):
            if time.perf_counter() >= deadline:
                return False
            time.sleep(poll)
        return True

    def release(self) -> None:
        if self._held:
            self._held = False
            self._lock.release()

    def __enter__(self) -> "Lease":
        self.acquire(block=True, timeout=60.0)
        return self

    def __exit__(self, *exc) -> None:
        self.release()


@runtime_checkable
class SharedTier(Protocol):
    """What the cache adapters need from a shared second level.

    Every ``get_*`` returns ``None`` on a miss — and a *damaged* entry
    (truncated file, digest mismatch, expired TTL) must also read as
    ``None`` with a counter bumped, never as wrong bytes or an exception.
    """

    #: True when entries are as trustworthy as this process's own memory
    #: (LocalTier); False when hits must be re-validated before serving
    #: (FileTier — certificate replay gates every remote pair hit).
    trusted: bool

    # -- window verdicts ----------------------------------------------------
    def get_verdict(
        self, ev_name: str, fingerprint: str
    ) -> Optional[Tuple[Optional[bool], float]]: ...

    def put_verdict(
        self, ev_name: str, fingerprint: str,
        verdict: Optional[bool], elapsed: float,
    ) -> None: ...

    def get_validity(self, ev_name: str, fingerprint: str) -> Optional[bool]: ...

    def put_validity(self, ev_name: str, fingerprint: str, valid: bool) -> None: ...

    # -- whole-pair verdicts + certificates ----------------------------------
    def get_pair(self, key: str) -> Optional[PairRecord]: ...

    def put_pair(self, key: str, record: PairRecord) -> None: ...

    # -- materializations ----------------------------------------------------
    def get_table(self, key: str) -> Optional[Tuple[Table, float]]: ...

    def put_table(self, key: str, table: Table, elapsed: float = 0.0) -> None: ...

    def release_table(self, key: str) -> None: ...

    # -- cross-process single-flight -----------------------------------------
    def lease(self, name: str) -> Lease: ...

    def stats(self) -> Dict[str, object]: ...


class LocalTier:
    """The local-dict backend: today's in-process sharing, behind the
    protocol.  Thread-safe; nothing persists, nothing crosses a process.

    TTL and byte budgets are accepted for interface parity but the local
    tier does not evict — the in-process caches it backs already carry
    their own LRU bounds (``VerdictCache.max_entries``,
    ``MaterializationStore`` byte budgets), so a second bound here would
    only duplicate accounting.
    """

    trusted = True

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._verdicts: Dict[Tuple[str, str], Tuple[Optional[bool], float]] = {}
        self._validity: Dict[Tuple[str, str], bool] = {}
        self._pairs: Dict[str, PairRecord] = {}
        self._tables: Dict[str, Tuple[Table, float]] = {}
        self._leases: Dict[str, threading.Lock] = {}
        self.hits = 0
        self.misses = 0

    # -- window verdicts ----------------------------------------------------
    def get_verdict(self, ev_name, fingerprint):
        with self._lock:
            got = self._verdicts.get((ev_name, fingerprint))
            self._count(got)
            return got

    def put_verdict(self, ev_name, fingerprint, verdict, elapsed):
        with self._lock:
            self._verdicts[(ev_name, fingerprint)] = (verdict, float(elapsed))

    def get_validity(self, ev_name, fingerprint):
        with self._lock:
            got = self._validity.get((ev_name, fingerprint))
            self._count(got)
            return got

    def put_validity(self, ev_name, fingerprint, valid):
        with self._lock:
            self._validity[(ev_name, fingerprint)] = bool(valid)

    # -- pairs ---------------------------------------------------------------
    def get_pair(self, key):
        with self._lock:
            got = self._pairs.get(key)
            self._count(got)
            return got

    def put_pair(self, key, record):
        with self._lock:
            self._pairs[key] = record

    # -- tables --------------------------------------------------------------
    def get_table(self, key):
        with self._lock:
            got = self._tables.get(key)
            self._count(got)
            return got

    def put_table(self, key, table, elapsed=0.0):
        with self._lock:
            self._tables[key] = (table, float(elapsed))

    def release_table(self, key):
        with self._lock:
            self._tables.pop(key, None)

    # -- leases --------------------------------------------------------------
    def lease(self, name: str) -> Lease:
        with self._lock:
            lock = self._leases.setdefault(name, threading.Lock())
        return Lease(lock)

    # -- stats ---------------------------------------------------------------
    def _count(self, got) -> None:  # caller holds the lock
        if got is None:
            self.misses += 1
        else:
            self.hits += 1

    def stats(self) -> Dict[str, object]:
        with self._lock:
            return {
                "backend": "local",
                "verdicts": len(self._verdicts),
                "validity": len(self._validity),
                "pairs": len(self._pairs),
                "tables": len(self._tables),
                "hits": self.hits,
                "misses": self.misses,
            }


def make_tier(
    shared_tier: str,
    tier_dir: Optional[str] = None,
    *,
    ttl_seconds: Optional[float] = None,
    byte_budget: Optional[int] = None,
):
    """Build the tier a config names: ``"local"`` → ``LocalTier`` (the
    default, today's behavior), ``"remote"`` → a ``FileTier`` rooted at
    ``tier_dir`` (required).  This is the single construction point the
    service, the fleet workers, and the benchmarks all use."""
    if shared_tier == "local":
        return LocalTier()
    if shared_tier == "remote":
        if tier_dir is None:
            raise ValueError("shared_tier='remote' needs a tier_dir")
        from repro.service.remote.filetier import FileTier

        return FileTier(
            tier_dir, ttl_seconds=ttl_seconds, byte_budget=byte_budget
        )
    raise ValueError(f"unknown shared tier {shared_tier!r}")
