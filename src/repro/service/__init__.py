"""Service layer: chain-level verification with cross-pair verdict reuse."""

from repro.service.chain import (
    ChainReport,
    PairReport,
    VersionChainSession,
    verify_chain,
)
from repro.core.ev.cache import VerdictCache

__all__ = [
    "ChainReport",
    "PairReport",
    "VersionChainSession",
    "verify_chain",
    "VerdictCache",
]
