"""Service layer: chain-level verification with cross-pair verdict reuse.

``VersionChainSession`` serves one client's version chain;
``VerificationService`` multiplexes many concurrent sessions over one
shared, thread-safe verdict cache (see ``repro.service.server``);
``VerificationFleet`` shards clients across worker *processes* over a
shared cache tier (``repro.service.fleet`` / ``repro.service.remote``).
"""

from repro.service.chain import (
    ChainReport,
    PairReport,
    VersionChainSession,
    verify_chain,
)
from repro.service.fleet import (
    ConsistentHashRing,
    FleetReport,
    FleetWorkerLost,
    VerificationFleet,
    shard_key,
)
from repro.service.pair_cache import PairEntry, PairVerdictCache
from repro.service.server import (
    ServiceBusy,
    ServiceClosed,
    ServiceReport,
    VerificationService,
)
from repro.core.ev.cache import VerdictCache

__all__ = [
    "ChainReport",
    "ConsistentHashRing",
    "FleetReport",
    "FleetWorkerLost",
    "PairEntry",
    "PairReport",
    "PairVerdictCache",
    "ServiceBusy",
    "ServiceClosed",
    "ServiceReport",
    "VerificationFleet",
    "VerificationService",
    "VersionChainSession",
    "verify_chain",
    "VerdictCache",
    "shard_key",
]
