"""Service layer: chain-level verification with cross-pair verdict reuse.

``VersionChainSession`` serves one client's version chain;
``VerificationService`` multiplexes many concurrent sessions over one
shared, thread-safe verdict cache (see ``repro.service.server``).
"""

from repro.service.chain import (
    ChainReport,
    PairReport,
    VersionChainSession,
    verify_chain,
)
from repro.service.pair_cache import PairEntry, PairVerdictCache
from repro.service.server import (
    ServiceBusy,
    ServiceClosed,
    ServiceReport,
    VerificationService,
)
from repro.core.ev.cache import VerdictCache

__all__ = [
    "ChainReport",
    "PairEntry",
    "PairReport",
    "PairVerdictCache",
    "ServiceBusy",
    "ServiceClosed",
    "ServiceReport",
    "VerificationService",
    "VersionChainSession",
    "verify_chain",
    "VerdictCache",
]
