"""Veer-driven materialization reuse (paper Use cases 1 & 2).

``ReuseManager.submit(dag, sources)`` — execute (or reuse) a new pipeline
version, rebased on the **operator-level** content-addressed store
(``repro.engine.store``).  Three reuse paths, strongest first:

  1. **digest identity** — any operator (sink *or interior*) whose Merkle
     content digest (upstream cone × concrete source bytes, see
     ``ExecutionPlan.digests``) is already materialized is served from the
     store, bit-identically, with no verification at all.  One changed
     filter late in a 40-operator pipeline re-executes its cone only.
  2. **certificate-backed semantic serving** — sinks the digests cannot
     serve are verified against previously-executed versions via Veer;
     a True verdict whose ``Certificate`` *replays green bound to the
     pair* yields a reuse frontier (``repro.core.frontier``) from which
     the sinks are served under the declared table semantics (Def 2.2),
     guarded by source-digest equality so a rebound source can never
     alias stale results.
  3. **partial execution** — whatever remains runs through
     ``ExecutionPlan.run`` with store serving + materialization on, so
     the executed cone's outputs become reusable for the next version.

The store is shared with checkpointing in spirit (same content-hash dedup
scheme), so equivalent results are stored once (Use case 2: no periodic
de-duplication pass needed), and every *semantic* reuse decision is
recorded with its replayable ``Certificate`` in ``self.certificates`` —
serving a cached result is the verdict that most needs an audit trail.

All timing uses ``time.perf_counter`` (monotonic), and
``ReuseStats.recompute_time_saved`` totals the recorded original compute
cost of every served table — benchmark deltas are immune to wall-clock
adjustments.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.api.certificate import Certificate, certificate_from_evidence
from repro.api.config import VeerConfig
from repro.api.registry import EVRegistry
from repro.core.dag import DataflowDAG
from repro.core.edits import EditMapping
from repro.core.ev.cache import VerdictCache
from repro.core.frontier import FrontierError, compute_reuse_frontier
from repro.core.verifier import Veer
from repro.engine.executor import ExecutionPlan
from repro.engine.store import DiskMaterializationStore
from repro.engine.table import Table

# The operator-level disk store now backs the reuse layer; the name is
# re-exported so `from repro.reuse import MaterializationStore` keeps
# importing, but the pre-refactor whole-table API (put(table) ->
# (digest, wrote) / get(digest)) is GONE — callers use the key-addressed
# repro.engine.store protocol (put(key, table) -> wrote / get(key)).
MaterializationStore = DiskMaterializationStore


@dataclass
class ReuseStats:
    submissions: int = 0
    sink_hits: int = 0
    sink_misses: int = 0
    executions: int = 0
    verify_time: float = 0.0           # perf_counter deltas
    execute_time: float = 0.0          # perf_counter deltas
    dedup_skipped_writes: int = 0
    verdict_cache_hits: int = 0
    certified_reuses: int = 0   # reuse decisions backed by a replayable cert
    # operator-level accounting (new with the content-addressed store)
    interior_hits: int = 0      # non-sink tables served during partial exec
    ops_executed: int = 0
    ops_reused: int = 0
    # recorded original compute seconds every served table avoided — the
    # honest counterpart to execute_time for benchmark deltas
    recompute_time_saved: float = 0.0


@dataclass
class _Version:
    vid: int
    dag: DataflowDAG
    digests: Dict[str, Optional[str]]   # op id -> content digest
    sink_keys: Dict[str, str]           # sink id -> store key actually served


class ReuseManager:
    def __init__(
        self,
        directory: str,
        veer: Optional[Veer] = None,
        *,
        config: Optional[VeerConfig] = None,
        registry: Optional[EVRegistry] = None,
        semantics: Optional[str] = None,
        verdict_cache: Optional[VerdictCache] = None,
        byte_budget: Optional[int] = None,
    ):
        """Preferred construction: ``config=VeerConfig(...)`` (the
        ``repro.api`` surface); passing a pre-built ``veer`` remains
        supported for older callers.  ``byte_budget`` bounds the on-disk
        store with LRU eviction.  Reuse decisions carry replayable
        certificates (``self.certificates``) — serving a stored result is
        exactly the kind of verdict an auditor wants evidence for."""
        if veer is not None and config is not None:
            raise ValueError("pass either veer or config, not both")
        if veer is None:
            config = config if config is not None else VeerConfig()
            veer = config.build(registry)
        if semantics is None:
            semantics = config.semantics if config is not None else "bag"
        self.config = config
        self.store = DiskMaterializationStore(directory, byte_budget=byte_budget)
        # EV verdicts live next to the materializations: one content-addressed
        # directory of reusable artifacts, shared across sessions (and with
        # VersionChainSession when handed the same cache).  An explicit
        # ``verdict_cache`` always wins; otherwise a verifier that already
        # carries a cache keeps it (never silently repoint shared state), and
        # only a cache-less verifier gets the store-local default.
        if verdict_cache is not None:
            veer.attach_cache(verdict_cache)
        elif veer.verdict_cache is None:
            verdict_cache = VerdictCache(self.store.dir / "ev_verdicts.json")
            veer.attach_cache(verdict_cache)
        else:
            verdict_cache = veer.verdict_cache
        self.verdict_cache = verdict_cache
        self.veer = veer
        self.semantics = semantics
        self.plane = config.plane if config is not None else "numpy"
        self._registry = registry
        self.versions: List[_Version] = []
        self.stats = ReuseStats()
        # certificate per reuse decision: (new version index, matched
        # version id, Certificate) — the audit trail for served results
        self.certificates: List[Tuple[int, int, Certificate]] = []

    def submit(
        self, dag: DataflowDAG, sources: Dict[str, Table]
    ) -> Dict[str, Table]:
        """Execute (or reuse) a pipeline version; returns sink tables."""
        self.stats.submissions += 1
        dag.validate()
        plan = ExecutionPlan(dag, sources, plane=self.plane)
        digests = plan.digests
        sinks = dag.sinks
        results: Dict[str, Table] = {}
        remaining = set(sinks)
        sink_keys: Dict[str, str] = {}

        # sinks the content digests cannot serve directly need Veer; the
        # rest resolve during partial execution (path 1, no verification)
        unresolved = {
            s for s in remaining
            if digests[s] is None or digests[s] not in self.store
        }
        if unresolved:
            self._serve_semantic(
                dag, digests, unresolved, remaining, results, sink_keys
            )

        if remaining:
            before = self.store.stats()
            t0 = time.perf_counter()
            res = plan.run(
                store=self.store,
                serve_from_store=True,
                materialize=True,
                keep=sorted(remaining),
            )
            self.stats.execute_time += time.perf_counter() - t0
            after = self.store.stats()
            if res.stats.ops_executed:
                self.stats.executions += 1
            self.stats.ops_executed += res.stats.ops_executed
            self.stats.ops_reused += res.stats.ops_reused
            self.stats.recompute_time_saved += res.stats.recompute_time_saved
            self.stats.dedup_skipped_writes += (
                after["dedup_skipped_writes"] - before["dedup_skipped_writes"]
            )
            reused = set(res.reused_ops)
            for s in remaining:
                results[s] = res.results[s]
                sink_keys[s] = digests[s]
                if s in reused:
                    self.stats.sink_hits += 1
                else:
                    self.stats.sink_misses += 1
            self.stats.interior_hits += res.stats.tables_served - len(
                remaining & reused
            )

        self.versions.append(
            _Version(len(self.versions), dag, digests, sink_keys)
        )
        self.verdict_cache.save()  # verdicts persist like materializations do
        return results

    def _serve_semantic(
        self,
        dag: DataflowDAG,
        digests: Dict[str, Optional[str]],
        unresolved: set,
        remaining: set,
        results: Dict[str, Table],
        sink_keys: Dict[str, str],
    ) -> None:
        """Path 2: verify against earlier versions, serve sinks off the
        certificate's reuse frontier (Def 2.2 equality, source-guarded)."""
        for prev in reversed(self.versions):
            if not unresolved:
                return
            t0 = time.perf_counter()
            verdict, vstats, evidence = self.veer.verify_with_evidence(
                prev.dag, dag, semantics=self.semantics
            )
            self.stats.verify_time += time.perf_counter() - t0
            self.stats.verdict_cache_hits += vstats.cache_hits
            if verdict is not True:
                continue
            cert = certificate_from_evidence(evidence)
            if cert is None:
                continue
            try:
                # reuse is only ever taken on a certificate that replays
                # green *bound to this pair* (tampered/truncated/foreign
                # evidence yields no frontier, never a wider one)
                frontier = compute_reuse_frontier(
                    cert, prev.dag, dag, registry=self._registry
                )
            except FrontierError:
                continue
            # source guard: Def 2.2 transfer needs the SAME concrete inputs —
            # every source of the matched version must map to a current
            # source bound to a byte-identical table
            fwd = EditMapping(cert.mapping).forward
            if not all(
                fwd.get(s) is not None
                and prev.digests.get(s) is not None
                and prev.digests.get(s) == digests.get(fwd[s])
                for s in prev.dag.sources
            ):
                continue
            # what may stand in for an unresolved sink: a frontier entry,
            # or — the Def 2.2 pair-level guarantee the True verdict itself
            # makes — the prev-version sink it maps to (corresponding sinks
            # of an equivalent pair are equal under the table semantics)
            bwd = EditMapping(cert.mapping).backward
            reusable = {**frontier.semantic, **frontier.exact}
            served = 0
            for q in sorted(unresolved):
                p = reusable.get(q)
                if p is None:
                    mapped = bwd.get(q)
                    if mapped is not None and mapped in prev.sink_keys:
                        p = mapped
                if p is None:
                    continue
                key = prev.sink_keys.get(p) or prev.digests.get(p)
                if key is None:
                    continue
                table = self.store.get(key)
                if table is None:
                    continue  # evicted or corrupt: fall through to execution
                results[q] = table
                sink_keys[q] = key
                unresolved.discard(q)
                remaining.discard(q)
                self.stats.sink_hits += 1
                self.stats.recompute_time_saved += self.store.recorded_cost(key)
                served += 1
            if served:
                # only decisions that actually served a result enter the
                # audit trail — an equivalent version whose sinks were
                # already covered reused nothing
                self.certificates.append((len(self.versions), prev.vid, cert))
                self.stats.certified_reuses += 1
