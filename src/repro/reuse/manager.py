"""Veer-driven materialization reuse (paper Use cases 1 & 2).

``ReuseManager.submit(dag, sources)`` — before executing a new pipeline
version, try to *verify* each of its sinks equivalent to an
already-executed version's sink via Veer; verified sinks are served from
the content-addressed store instead of recomputed.  The store is shared
with checkpointing (same hashing scheme), so equivalent results are stored
once (Use case 2: no periodic de-duplication pass needed).

Built on the ``repro.api`` surface: construct with ``config=VeerConfig``
(EVs by name), and every reuse decision is recorded with its replayable
``Certificate`` in ``self.certificates`` — serving a cached result is the
verdict that most needs an audit trail.
"""

from __future__ import annotations

import hashlib
import json
import pathlib
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.api.certificate import Certificate, certificate_from_evidence
from repro.api.config import VeerConfig
from repro.api.registry import EVRegistry
from repro.core.dag import DataflowDAG
from repro.core.edits import identity_mapping
from repro.core.ev.cache import VerdictCache
from repro.core.verifier import Veer
from repro.engine.executor import execute
from repro.engine.table import Table


@dataclass
class ReuseStats:
    submissions: int = 0
    sink_hits: int = 0
    sink_misses: int = 0
    executions: int = 0
    verify_time: float = 0.0
    execute_time: float = 0.0
    dedup_skipped_writes: int = 0
    verdict_cache_hits: int = 0
    certified_reuses: int = 0   # reuse decisions backed by a replayable cert


@dataclass
class _Version:
    vid: int
    dag: DataflowDAG
    sink_objects: Dict[str, str]  # sink id -> object digest


class MaterializationStore:
    def __init__(self, directory: str):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)

    def put(self, table: Table) -> Tuple[str, bool]:
        h = hashlib.sha256()
        h.update(repr(table.order).encode())
        for c in table.order:
            arr = table.cols[c]
            h.update(np.asarray(arr, dtype=object if arr.dtype == object else arr.dtype).tobytes() if arr.dtype != object else repr(list(arr)).encode())
        digest = h.hexdigest()[:32]
        path = self.dir / f"{digest}.npz"
        if path.exists():
            return digest, False
        payload = {}
        meta = {"order": table.order, "object_cols": []}
        for c in table.order:
            arr = table.cols[c]
            if arr.dtype == object:
                meta["object_cols"].append(c)
                payload[c] = np.array([json.dumps(_jsonable(v)) for v in arr])
            else:
                payload[c] = arr
        np.savez(path, **payload)
        (self.dir / f"{digest}.json").write_text(json.dumps(meta))
        return digest, True

    def get(self, digest: str) -> Table:
        meta = json.loads((self.dir / f"{digest}.json").read_text())
        data = np.load(self.dir / f"{digest}.npz", allow_pickle=False)
        cols = {}
        for c in meta["order"]:
            arr = data[c]
            if c in meta["object_cols"]:
                arr = np.array([json.loads(s) for s in arr], dtype=object)
            cols[c] = arr
        return Table(cols, meta["order"])


def _jsonable(v):
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (list, tuple, np.ndarray)):
        return [_jsonable(x) for x in v]
    return v


class ReuseManager:
    def __init__(
        self,
        directory: str,
        veer: Optional[Veer] = None,
        *,
        config: Optional[VeerConfig] = None,
        registry: Optional[EVRegistry] = None,
        semantics: Optional[str] = None,
        verdict_cache: Optional[VerdictCache] = None,
    ):
        """Preferred construction: ``config=VeerConfig(...)`` (the
        ``repro.api`` surface); passing a pre-built ``veer`` remains
        supported for older callers.  Reuse decisions carry replayable
        certificates (``self.certificates``) — serving a stored result is
        exactly the kind of verdict an auditor wants evidence for."""
        if veer is not None and config is not None:
            raise ValueError("pass either veer or config, not both")
        if veer is None:
            config = config if config is not None else VeerConfig()
            veer = config.build(registry)
        if semantics is None:
            semantics = config.semantics if config is not None else "bag"
        self.config = config
        self.store = MaterializationStore(directory)
        # EV verdicts live next to the materializations: one content-addressed
        # directory of reusable artifacts, shared across sessions (and with
        # VersionChainSession when handed the same cache).  An explicit
        # ``verdict_cache`` always wins; otherwise a verifier that already
        # carries a cache keeps it (never silently repoint shared state), and
        # only a cache-less verifier gets the store-local default.
        if verdict_cache is not None:
            veer.attach_cache(verdict_cache)
        elif veer.verdict_cache is None:
            verdict_cache = VerdictCache(self.store.dir / "ev_verdicts.json")
            veer.attach_cache(verdict_cache)
        else:
            verdict_cache = veer.verdict_cache
        self.verdict_cache = verdict_cache
        self.veer = veer
        self.semantics = semantics
        self.versions: List[_Version] = []
        self.stats = ReuseStats()
        # certificate per reuse decision: (new version index, matched
        # version id, Certificate) — the audit trail for served results
        self.certificates: List[Tuple[int, int, Certificate]] = []

    def submit(
        self, dag: DataflowDAG, sources: Dict[str, Table]
    ) -> Dict[str, Table]:
        """Execute (or reuse) a pipeline version; returns sink tables."""
        self.stats.submissions += 1
        dag.validate()
        sinks = dag.sinks
        results: Dict[str, Table] = {}
        remaining = set(sinks)

        for prev in reversed(self.versions):
            if not remaining:
                break
            t0 = time.perf_counter()
            verdict, vstats, evidence = self.veer.verify_with_evidence(
                prev.dag, dag, semantics=self.semantics
            )
            self.stats.verify_time += time.perf_counter() - t0
            self.stats.verdict_cache_hits += vstats.cache_hits
            if verdict is True:
                mapping = identity_mapping(prev.dag, dag).forward
                served = 0
                for psink, digest in prev.sink_objects.items():
                    qsink = mapping.get(psink)
                    if qsink in remaining:
                        results[qsink] = self.store.get(digest)
                        remaining.discard(qsink)
                        self.stats.sink_hits += 1
                        served += 1
                if served:
                    # only decisions that actually served a result enter the
                    # audit trail — an equivalent version whose sinks were
                    # already covered reused nothing
                    cert = certificate_from_evidence(evidence)
                    if cert is not None:
                        self.certificates.append((len(self.versions), prev.vid, cert))
                        self.stats.certified_reuses += 1

        if remaining:
            t0 = time.perf_counter()
            executed = execute(dag, sources)
            self.stats.execute_time += time.perf_counter() - t0
            self.stats.executions += 1
            for s in remaining:
                results[s] = executed[s]
                self.stats.sink_misses += 1

        sink_objects = {}
        for s in sinks:
            digest, wrote = self.store.put(results[s])
            if not wrote:
                self.stats.dedup_skipped_writes += 1
            sink_objects[s] = digest
        self.versions.append(_Version(len(self.versions), dag, sink_objects))
        self.verdict_cache.save()  # verdicts persist like materializations do
        return results
