from repro.reuse.manager import MaterializationStore, ReuseManager, ReuseStats

__all__ = ["MaterializationStore", "ReuseManager", "ReuseStats"]
