"""GQA attention block: train/prefill (flash path) + KV-cache decode.

Attention variants per layer kind (configs.base):
  attn        — global causal
  attn_local  — sliding window (gemma3 5:1 local:global)
  attn_chunk  — chunked local (llama4 iRoPE-style)
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.distributed.sharding import constrain
from repro.models.layers import PD, dense, rms_norm, rope


def attn_defs(cfg: ArchConfig) -> Dict[str, PD]:
    d, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    return {
        "ln": PD((d,), (None,), init="ones"),
        "wq": PD((d, H * Dh), (None, "tp")),
        "wk": PD((d, KV * Dh), (None, "tp")),
        "wv": PD((d, KV * Dh), (None, "tp")),
        "wo": PD((H * Dh, d), ("tp", None)),
    }


def _kind_masks(kind: str, cfg: ArchConfig) -> Dict[str, Optional[int]]:
    if kind == "attn_local":
        return {"window": cfg.window, "chunk": None}
    if kind == "attn_chunk":
        return {"window": None, "chunk": cfg.chunk}
    return {"window": None, "chunk": None}


def attn_block(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,              # (B, S, d)
    cfg: ArchConfig,
    kind: str,
    *,
    positions: Optional[jnp.ndarray] = None,   # (S,)
    causal: bool = True,
    attn_impl: str = "reference",
) -> jnp.ndarray:
    B, S, d = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    h = rms_norm(x, p["ln"], cfg.rms_eps)
    # Megatron-style head parallelism: attention is fully LOCAL per head.
    # (Perf iteration 1, EXPERIMENTS.md §Perf: sequence-sharding activations
    # instead put per-kv-block all-reduces INSIDE the flash loops —
    # 640-trip collectives dominated the step.)
    hs = cfg.head_sharded_attn

    def _c(t, spec):
        return constrain(t, spec) if hs else t

    q = _c(dense(h, p["wq"]).reshape(B, S, H, Dh), ("dp", None, "tp", None))
    k = _c(dense(h, p["wk"]).reshape(B, S, KV, Dh), ("dp", None, _kv_axis(cfg), None))
    v = _c(dense(h, p["wv"]).reshape(B, S, KV, Dh), ("dp", None, _kv_axis(cfg), None))
    if positions is None:
        positions = jnp.arange(S)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    masks = _kind_masks(kind, cfg)
    o = kops.flash_attention(
        q, k, v, causal=causal, impl=attn_impl, **masks
    )
    o = _c(o, ("dp", None, "tp", None))
    return x + dense(o.reshape(B, S, H * Dh), p["wo"])


def _kv_axis(cfg: ArchConfig):
    # KV heads shard over tp only when divisible (GQA kv=2..16 vs tp=16);
    # otherwise replicate KV heads (cheap) and keep Q heads sharded.
    return "tp" if cfg.n_kv_heads % 16 == 0 else None


# ---------------------------------------------------------------------------
# Decode with KV cache
# ---------------------------------------------------------------------------


def attn_cache_shape(cfg: ArchConfig, batch: int, seq: int) -> Dict[str, Any]:
    KV, Dh = cfg.n_kv_heads, cfg.d_head
    return {
        "k": jax.ShapeDtypeStruct((batch, seq, KV, Dh), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((batch, seq, KV, Dh), jnp.bfloat16),
    }


def attn_cache_spec(long_context: bool) -> Dict[str, Tuple]:
    # decode_32k: batch over dp, kv-seq over tp (KV memory dominates).
    # long_500k (batch=1): sequence over BOTH axes.
    if long_context:
        return {"k": (None, ("dp", "tp"), None, None),
                "v": (None, ("dp", "tp"), None, None)}
    return {"k": ("dp", "tp", None, None), "v": ("dp", "tp", None, None)}


def attn_decode_block(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,              # (B, 1, d) the new token's activations
    cache: Dict[str, jnp.ndarray],
    pos: jnp.ndarray,            # scalar int32
    cfg: ArchConfig,
    kind: str,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    B, _, d = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    h = rms_norm(x, p["ln"], cfg.rms_eps)
    q = dense(h, p["wq"]).reshape(B, 1, H, Dh)
    k = dense(h, p["wk"]).reshape(B, 1, KV, Dh)
    v = dense(h, p["wv"]).reshape(B, 1, KV, Dh)
    q = rope(q, pos[None], cfg.rope_theta)
    k = rope(k, pos[None], cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0)
    )
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0)
    )
    masks = _kind_masks(kind, cfg)
    o = kref.decode_attention_reference(
        q[:, 0], k_cache, v_cache, pos, **masks
    )
    out = x + dense(o.reshape(B, 1, H * Dh), p["wo"])
    return out, {"k": k_cache, "v": v_cache}
