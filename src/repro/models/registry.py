"""Uniform Model facade over all families: params, specs, inputs, steps.

Everything the launcher / dry-run / tests need for an (arch × shape) cell:

  model.init(rng)                      real params (smoke tests, examples)
  model.abstract_params()              ShapeDtypeStructs (dry-run, no alloc)
  model.param_specs()                  logical PartitionSpec tree
  model.input_specs(shape)             (inputs SDS tree, logical spec tree)
  model.loss(params, batch)            train/prefill loss
  model.decode_step(params, caches, token, pos)
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec as E
from repro.models import transformer as T
from repro.models import vlm as V
from repro.models.layers import abstract_tree, init_tree, spec_tree


@dataclass
class Model:
    cfg: ArchConfig
    attn_impl: str = "reference"
    remat: bool = True

    # -- params ---------------------------------------------------------------
    def param_defs(self):
        if self.cfg.family == "audio":
            defs = E.encdec_param_defs(self.cfg)
        elif self.cfg.family == "vlm":
            defs = V.vlm_param_defs(self.cfg)
        else:
            defs = T.lm_param_defs(self.cfg)
        if self.cfg.zero3_weights:
            defs = _apply_zero3(defs)
        return defs

    def init(self, rng: jax.Array):
        return init_tree(self.param_defs(), rng)

    def abstract_params(self):
        return abstract_tree(self.param_defs())

    def param_specs(self):
        return spec_tree(self.param_defs())

    # -- inputs -----------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        if shape.kind in ("train", "prefill"):
            inputs = {"tokens": jax.ShapeDtypeStruct((B, S + 1), jnp.int32)}
            specs = {"tokens": ("dp", None)}
            if cfg.family == "vlm":
                inputs["patch_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.vision.n_patches, cfg.vision.d_vision), jnp.bfloat16
                )
                specs["patch_embeds"] = ("dp", None, None)
            if cfg.family == "audio":
                inputs["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.encoder.n_frames, cfg.encoder.d_frame), jnp.bfloat16
                )
                specs["frames"] = ("dp", None, None)
            return inputs, specs
        # decode: one new token against a seq_len cache
        long_ctx = B < 16  # batch can't cover the dp axis — shard the sequence
        caches = (
            E.encdec_cache_shapes(cfg, B, S)
            if cfg.family == "audio"
            else T.lm_cache_shapes(cfg, B, S)
        )
        cache_specs = (
            E.encdec_cache_specs(cfg, long_ctx)
            if cfg.family == "audio"
            else T.lm_cache_specs(cfg, long_ctx)
        )
        inputs = {
            "caches": caches,
            "token": jax.ShapeDtypeStruct((B,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
        specs = {
            "caches": cache_specs,
            "token": ("dp",) if not long_ctx else (None,),
            "pos": (),
        }
        return inputs, specs

    # -- steps ---------------------------------------------------------------------
    def loss(self, params, batch):
        cfg = self.cfg
        if cfg.family == "audio":
            return E.encdec_loss(
                params, batch, cfg, attn_impl=self.attn_impl, remat=self.remat
            )
        if cfg.family == "vlm":
            return V.vlm_loss(
                params, batch, cfg, attn_impl=self.attn_impl, remat=self.remat
            )
        return T.lm_loss(
            params, batch, cfg, attn_impl=self.attn_impl, remat=self.remat
        )

    def decode_step(self, params, caches, token, pos):
        cfg = self.cfg
        if cfg.family == "audio":
            return E.encdec_decode_step(params, caches, token, pos, cfg)
        return T.lm_decode_step(params, caches, token, pos, cfg)

    def forward(self, params, tokens, **kw):
        return T.lm_forward(
            params, tokens, self.cfg, attn_impl=self.attn_impl, remat=self.remat, **kw
        )

    def forward_step(self, params, batch):
        """Inference prefill: batch → logits (serve-side prefill compute)."""
        cfg = self.cfg
        tokens = batch["tokens"][:, :-1]
        if cfg.family == "audio":
            return E.encdec_forward(
                params, batch["frames"], tokens, cfg,
                attn_impl=self.attn_impl, remat=self.remat,
            )
        prefix = None
        if cfg.family == "vlm":
            from repro.models.layers import dense

            prefix = dense(
                batch["patch_embeds"].astype(jnp.bfloat16), params["vision_proj"]
            )
        return T.lm_forward(
            params, tokens, cfg,
            attn_impl=self.attn_impl, remat=self.remat, prefix_embeds=prefix,
        )

    def serve_step_fn(self) -> Callable:
        def serve_step(params, caches, token, pos):
            return self.decode_step(params, caches, token, pos)

        return serve_step

    def loss_fn(self) -> Callable:
        def loss(params, batch):
            return self.loss(params, batch)

        return loss

    def n_params(self) -> int:
        total = 0
        for sds in jax.tree_util.tree_leaves(self.abstract_params()):
            n = 1
            for s in sds.shape:
                n *= s
            total += n
        return total

    def n_active_params(self) -> int:
        """Active per token (MoE counts top_k of n_experts)."""
        if self.cfg.moe is None:
            return self.n_params()
        m = self.cfg.moe
        total = 0
        for path, sds in jax.tree_util.tree_flatten_with_path(self.abstract_params())[0]:
            n = 1
            for s in sds.shape:
                n *= s
            keys = "/".join(str(getattr(k, "key", k)) for k in path)
            if "ffn_moe" in keys and ("w_in" in keys or "w_out" in keys):
                n = n * m.top_k // m.n_experts
            total += n
        return total


def _apply_zero3(defs):
    """ZeRO-3-style: dp-shard every ≥2D weight on the first unsharded dim
    divisible by 32 (valid on both production meshes)."""
    from repro.models.layers import PD

    def one(pd):
        if not isinstance(pd, PD) or len(pd.shape) < 2:
            return pd
        axes = {a for s in pd.spec for a in ((s,) if isinstance(s, str) else (s or ()))}
        if "dp" in axes:
            return pd
        spec = list(pd.spec)
        for i, (ax, dim) in enumerate(zip(spec, pd.shape)):
            if ax is None and dim % 32 == 0 and dim >= 32:
                spec[i] = "dp"
                return PD(pd.shape, tuple(spec), pd.init, pd.scale, pd.dtype)
        return pd

    return jax.tree_util.tree_map(one, defs, is_leaf=lambda x: isinstance(x, PD))


def build_model(cfg: ArchConfig, **kw) -> Model:
    return Model(cfg, **kw)
