"""Encoder-decoder transformer (whisper-tiny backbone).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, n_frames, d).  Encoder = bidirectional
self-attention stack; decoder = causal self-attention + cross-attention.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import attention as A
from repro.models.layers import (
    PD,
    dense,
    mlp_block,
    mlp_defs,
    rms_norm,
    rope,
    stack_defs,
)

COMPUTE_DTYPE = jnp.bfloat16


def _xattn_defs(cfg: ArchConfig) -> Dict[str, PD]:
    d, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    return {
        "ln": PD((d,), (None,), init="ones"),
        "wq": PD((d, H * Dh), (None, "tp")),
        "wk": PD((d, KV * Dh), (None, "tp")),
        "wv": PD((d, KV * Dh), (None, "tp")),
        "wo": PD((H * Dh, d), ("tp", None)),
    }


def encdec_param_defs(cfg: ArchConfig) -> Dict[str, Any]:
    enc = cfg.encoder
    d, V = cfg.d_model, cfg.vocab
    enc_layer = {
        "self": A.attn_defs(cfg),
        "ffn": mlp_defs(d, cfg.d_ff),
    }
    dec_layer = {
        "self": A.attn_defs(cfg),
        "cross": _xattn_defs(cfg),
        "ffn": mlp_defs(d, cfg.d_ff),
    }
    from repro.models.transformer import vocab_axis

    return {
        "embed": PD((V, d), (vocab_axis(V), None), scale=1.0 / (d ** 0.5)),
        "enc_pos": PD((enc.n_frames, d), (None, None)),
        "enc": stack_defs(enc_layer, enc.n_layers),
        "dec": stack_defs(dec_layer, cfg.n_layers),
        "enc_ln": PD((d,), (None,), init="ones"),
        "final_ln": PD((d,), (None,), init="ones"),
        "lm_head": PD((d, V), (None, vocab_axis(V))),
    }


def _cross_attn(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,          # (B, S, d) decoder states
    enc_k: jnp.ndarray,      # (B, T, KV, Dh) precomputed
    enc_v: jnp.ndarray,
    cfg: ArchConfig,
) -> jnp.ndarray:
    B, S, d = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    h = rms_norm(x, p["ln"], cfg.rms_eps)
    q = dense(h, p["wq"]).reshape(B, S, H, Dh)
    o = kref.attention_reference(q, enc_k, enc_v, causal=False)
    return x + dense(o.reshape(B, S, H * Dh), p["wo"])


def _enc_kv(p: Dict[str, jnp.ndarray], enc_out: jnp.ndarray, cfg: ArchConfig):
    B, T, d = enc_out.shape
    KV, Dh = cfg.n_kv_heads, cfg.d_head
    k = dense(enc_out, p["wk"]).reshape(B, T, KV, Dh)
    v = dense(enc_out, p["wv"]).reshape(B, T, KV, Dh)
    return k, v


def encode(params: Dict[str, Any], frames: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """frames: (B, n_frames, d) stub frontend output → encoder states."""
    x = frames.astype(COMPUTE_DTYPE) + params["enc_pos"].astype(COMPUTE_DTYPE)[None]
    x = constrain(x, ("dp", None, None))

    def layer(xc, lp):
        xc = A.attn_block(lp["self"], xc, cfg, "attn", causal=False)
        xc = mlp_block(lp["ffn"], xc, cfg.rms_eps)
        return constrain(xc, ("dp", None, None)), None

    x, _ = jax.lax.scan(layer, x, params["enc"])
    return rms_norm(x, params["enc_ln"], cfg.rms_eps)


def encdec_forward(
    params: Dict[str, Any],
    frames: jnp.ndarray,   # (B, T, d) stub frontend output
    inputs: jnp.ndarray,   # (B, S) decoder tokens
    cfg: ArchConfig,
    *,
    attn_impl: str = "reference",
    remat: bool = True,
) -> jnp.ndarray:
    enc_out = encode(params, frames, cfg)
    B, S = inputs.shape
    x = jnp.take(params["embed"], inputs, axis=0).astype(COMPUTE_DTYPE)
    positions = jnp.arange(S)

    def layer(xc, lp):
        xc = A.attn_block(
            lp["self"], xc, cfg, "attn", positions=positions, attn_impl=attn_impl
        )
        k, v = _enc_kv(lp["cross"], enc_out, cfg)
        xc = _cross_attn(lp["cross"], xc, k, v, cfg)
        xc = mlp_block(lp["ffn"], xc, cfg.rms_eps)
        return constrain(xc, ("dp", None, None)), None

    body = jax.checkpoint(lambda c, p: layer(c, p)) if remat else layer
    x, _ = jax.lax.scan(body, x, params["dec"])
    x = rms_norm(x, params["final_ln"], cfg.rms_eps)
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))


def encdec_loss(
    params: Dict[str, Any],
    batch: Dict[str, jnp.ndarray],  # frames (B,T,d), tokens (B,S+1)
    cfg: ArchConfig,
    *,
    attn_impl: str = "reference",
    remat: bool = True,
) -> jnp.ndarray:
    frames, tokens = batch["frames"], batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    logits = encdec_forward(
        params, frames, inputs, cfg, attn_impl=attn_impl, remat=remat
    ).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def encdec_cache_shapes(cfg: ArchConfig, batch: int, seq: int) -> Dict[str, Any]:
    enc = cfg.encoder
    KV, Dh = cfg.n_kv_heads, cfg.d_head
    per = {
        "self": A.attn_cache_shape(cfg, batch, seq),
        "cross_k": jax.ShapeDtypeStruct((batch, enc.n_frames, KV, Dh), jnp.bfloat16),
        "cross_v": jax.ShapeDtypeStruct((batch, enc.n_frames, KV, Dh), jnp.bfloat16),
    }
    return {
        "dec": jax.tree_util.tree_map(
            lambda sds: jax.ShapeDtypeStruct((cfg.n_layers,) + sds.shape, sds.dtype),
            per,
        )
    }


def encdec_cache_specs(cfg: ArchConfig, long_context: bool) -> Dict[str, Any]:
    per = {
        "self": A.attn_cache_spec(long_context),
        # whisper has 6 KV heads (not divisible by tp=16) and only 1500
        # encoder frames — keep cross-KV replicated over tp
        "cross_k": ("dp", None, None, None),
        "cross_v": ("dp", None, None, None),
    }
    return {
        "dec": jax.tree_util.tree_map(
            lambda s: (None,) + s, per, is_leaf=lambda s: isinstance(s, tuple)
        )
    }


def encdec_decode_step(
    params: Dict[str, Any],
    caches: Dict[str, Any],
    token: jnp.ndarray,
    pos: jnp.ndarray,
    cfg: ArchConfig,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Decoder step against precomputed cross-KV (encoder ran at prefill)."""
    x = jnp.take(params["embed"], token, axis=0)[:, None, :].astype(COMPUTE_DTYPE)

    def layer(xc, inp):
        lp, cc = inp
        xc, new_self = A.attn_decode_block(lp["self"], xc, cc["self"], pos, cfg, "attn")
        B = xc.shape[0]
        H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
        h = rms_norm(xc, lp["cross"]["ln"], cfg.rms_eps)
        q = dense(h, lp["cross"]["wq"]).reshape(B, H, Dh)
        o = kref.decode_attention_reference(
            q, cc["cross_k"], cc["cross_v"], jnp.asarray(cc["cross_k"].shape[1] - 1)
        )
        xc = xc + dense(o.reshape(B, 1, H * Dh), lp["cross"]["wo"])
        xc = mlp_block(lp["ffn"], xc, cfg.rms_eps)
        return xc, {"self": new_self, "cross_k": cc["cross_k"], "cross_v": cc["cross_v"]}

    x, new_dec = jax.lax.scan(layer, x, (params["dec"], caches["dec"]))
    x = rms_norm(x, params["final_ln"], cfg.rms_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))[:, 0]
    return logits.astype(jnp.float32), {"dec": new_dec}
