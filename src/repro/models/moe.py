"""Mixture-of-Experts block — GShard-style capacity dispatch.

Experts are expert-parallel over the "tp" logical axis (16 experts on a
16-wide model axis = 1 expert/group for llama4-scout; 8/group for maverick's
128).  Dispatch/combine einsums against the token dimension lower to
all-to-all-style collectives under GSPMD — the collective roofline term for
the MoE cells comes from here.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoECfg
from repro.models.layers import PD, dense, rms_norm


def moe_defs(cfg: ArchConfig) -> Dict[str, PD]:
    d = cfg.d_model
    m = cfg.moe
    # experts EP over tp; within-expert dims additionally dp-sharded so the
    # 100-400B expert stacks fit (ZeRO-3-style weight sharding — GSPMD
    # all-gathers per layer, overlapped with the scan)
    return {
        "ln": PD((d,), (None,), init="ones"),
        "w_gate": PD((d, m.n_experts), (None, None)),
        "w_in": PD((m.n_experts, d, 2 * m.d_ff_expert), ("tp", None, "dp")),
        "w_out": PD((m.n_experts, m.d_ff_expert, d), ("tp", "dp", None)),
    }


def moe_block(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,  # (B, S, d)
    cfg: ArchConfig,
    *,
    capacity_factor: Optional[float] = None,
    dispatch: str = "gather",   # "gather" (sparse, O(T·d)) | "einsum" (GShard)
) -> jnp.ndarray:
    """GShard-style grouped dispatch: each batch row is a dispatch group with
    capacity C = ceil(S·K·cf/E) — keeps every buffer O(local tokens), unlike
    a global-capacity formulation whose (T, E, C_global) dispatch tensor is
    quadratic in tokens."""
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.n_experts, m.top_k
    cf = capacity_factor if capacity_factor is not None else m.capacity_factor
    C = max(K, int(S * K * cf / E))

    h = rms_norm(x, p["ln"], cfg.rms_eps)                       # (B, S, d)
    logits = jnp.einsum(
        "bsd,de->bse", h.astype(jnp.float32), p["w_gate"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)               # (B, S, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # rank of each (s, k) assignment within its expert, per group (k-major)
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)       # (B, S, K, E)
    flat = onehot.reshape(B, S * K, E)
    ranks = jnp.cumsum(flat, axis=1) - flat                     # (B, S*K, E)
    pos_in_expert = (ranks * flat).sum(-1).reshape(B, S, K)
    keep = pos_in_expert < C
    if dispatch == "einsum":
        slot = jax.nn.one_hot(
            jnp.where(keep, pos_in_expert, C), C + 1, dtype=h.dtype
        )[..., :C]                                               # (B, S, K, C)
        eoh = jax.nn.one_hot(gate_idx, E, dtype=h.dtype)         # (B, S, K, E)
        disp = jnp.einsum("bske,bskc->bsec", eoh, slot)          # (B, S, E, C)
        comb = jnp.einsum(
            "bske,bskc->bsec",
            eoh * (gate_vals.astype(h.dtype) * keep.astype(h.dtype))[..., None],
            slot,
        )

    if dispatch == "einsum":
        expert_in = jnp.einsum("bsec,bsd->becd", disp, h)        # (B, E, C, d)
    else:
        # gather dispatch (§Perf: the one-hot dispatch matmul costs
        # B·S·E·C·d flops ≈ a d×d matmul per MoE layer — pure waste; a
        # token-index gather moves the same data at O(tokens·d))
        # slot_token[b, e, c] = index of the token in slot (e, c), or S (pad)
        slot_token = jnp.full((B, E, C), S, dtype=jnp.int32)
        s_idx = jnp.broadcast_to(jnp.arange(S)[None, :, None], gate_idx.shape)
        slot_token = slot_token.at[
            jnp.arange(B)[:, None, None],
            gate_idx,
            jnp.where(keep, pos_in_expert, C),  # C = out of bounds -> dropped
        ].set(s_idx, mode="drop")
        h_pad = jnp.concatenate([h, jnp.zeros((B, 1, d), h.dtype)], axis=1)
        expert_in = jnp.take_along_axis(
            h_pad, slot_token.reshape(B, E * C)[:, :, None], axis=1
        ).reshape(B, E, C, d)
    gates_ups = jnp.einsum("becd,edf->becf", expert_in, p["w_in"].astype(h.dtype))
    gate, up = jnp.split(gates_ups, 2, axis=-1)
    act = jax.nn.silu(gate) * up
    expert_out = jnp.einsum("becf,efd->becd", act, p["w_out"].astype(h.dtype))
    if dispatch == "einsum":
        y = jnp.einsum("bsec,becd->bsd", comb, expert_out)       # (B, S, d)
    else:
        # combine by gathering each token's (expert, slot) output
        flat_out = expert_out.reshape(B, E * C, d)
        tok_slot = gate_idx * C + jnp.where(keep, pos_in_expert, 0)  # (B,S,K)
        gathered = jnp.take_along_axis(
            flat_out, tok_slot.reshape(B, S * K)[:, :, None], axis=1
        ).reshape(B, S, K, d)
        w = (gate_vals * keep.astype(gate_vals.dtype)).astype(h.dtype)
        y = jnp.einsum("bskd,bsk->bsd", gathered, w)
    return x + y
