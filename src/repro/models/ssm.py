"""Mamba-2 block (SSD mixer) — train (chunked SSD) + single-token decode.

The SSD inner scan goes through ``repro.kernels.ops.ssd`` (Pallas kernel on
TPU, chunked reference elsewhere).  Decode carries (conv buffer, SSM state).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.distributed.sharding import constrain
from repro.models.layers import PD, dense, rms_norm


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + H
    return s, d_inner, H, conv_dim, d_in_proj


def mamba_defs(cfg: ArchConfig) -> Dict[str, PD]:
    """Split (not fused) projections: slicing a fused tp-sharded in_proj at
    non-shard-aligned offsets forced an all-to-all per layer per pass (§Perf
    iteration 5) — separate column-parallel projections are shard-clean and
    mathematically identical."""
    s, d_inner, H, conv_dim, d_in_proj = _dims(cfg)
    d = cfg.d_model
    gn_axis = "tp" if (s.n_groups * s.d_state) % 16 == 0 else None
    return {
        "ln": PD((d,), (None,), init="ones"),
        "z_proj": PD((d, d_inner), (None, "tp")),
        "x_proj": PD((d, d_inner), (None, "tp")),
        "b_proj": PD((d, s.n_groups * s.d_state), (None, gn_axis)),
        "c_proj": PD((d, s.n_groups * s.d_state), (None, gn_axis)),
        "dt_proj": PD((d, H), (None, "tp")),
        "conv_x_w": PD((s.d_conv, d_inner), (None, "tp"), scale=0.1),
        "conv_x_b": PD((d_inner,), ("tp",), init="zeros"),
        "conv_b_w": PD((s.d_conv, s.n_groups * s.d_state), (None, gn_axis), scale=0.1),
        "conv_b_b": PD((s.n_groups * s.d_state,), (gn_axis,), init="zeros"),
        "conv_c_w": PD((s.d_conv, s.n_groups * s.d_state), (None, gn_axis), scale=0.1),
        "conv_c_b": PD((s.n_groups * s.d_state,), (gn_axis,), init="zeros"),
        "A_log": PD((H,), ("tp",), init="zeros"),
        "D": PD((H,), ("tp",), init="ones"),
        "dt_bias": PD((H,), ("tp",), init="zeros"),
        "gn": PD((d_inner,), ("tp",), init="ones"),
        "out_proj": PD((d_inner, d), ("tp", None)),
    }


def _causal_conv(x, w, b, d_conv):
    """Depthwise causal conv over the sequence axis + SiLU."""
    S = x.shape[1]
    pad = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + S, :] * w[i].astype(x.dtype) for i in range(d_conv)
    ) + b.astype(x.dtype)
    return jax.nn.silu(out)


def _split_zxbcdt(zxbcdt: jnp.ndarray, cfg: ArchConfig):
    s, d_inner, H, conv_dim, _ = _dims(cfg)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner : d_inner + conv_dim]
    dt = zxbcdt[..., d_inner + conv_dim :]
    return z, xBC, dt


def _split_xbc(xBC: jnp.ndarray, cfg: ArchConfig):
    s, d_inner, H, _, _ = _dims(cfg)
    x = xBC[..., :d_inner]
    Bm = xBC[..., d_inner : d_inner + s.n_groups * s.d_state]
    Cm = xBC[..., d_inner + s.n_groups * s.d_state :]
    return x, Bm, Cm


def mamba_block(
    p: Dict[str, jnp.ndarray],
    x_in: jnp.ndarray,  # (B, S, d)
    cfg: ArchConfig,
    *,
    ssd_impl: str = "reference",
) -> jnp.ndarray:
    s, d_inner, H, conv_dim, _ = _dims(cfg)
    B, S, d = x_in.shape
    h = rms_norm(x_in, p["ln"], cfg.rms_eps)
    # shard-clean split projections (see mamba_defs)
    z = dense(h, p["z_proj"])
    xs = _causal_conv(dense(h, p["x_proj"]), p["conv_x_w"], p["conv_x_b"], s.d_conv)
    Bm = _causal_conv(dense(h, p["b_proj"]), p["conv_b_w"], p["conv_b_b"], s.d_conv)
    Cm = _causal_conv(dense(h, p["c_proj"]), p["conv_c_w"], p["conv_c_b"], s.d_conv)
    dt = dense(h, p["dt_proj"])

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    # NOTE (§Perf iterations 4/6): forcing head-sharding here was REFUTED —
    # GSPMD's propagation from the seq-sharded interlayer activations keeps
    # the SSD collective-free (t_coll 17 s vs 34-37 s with forced specs).
    # The split projections above are kept: they remove the shard-misaligned
    # slicing reshards regardless of propagation choices.
    xh = xs.reshape(B, S, H, s.head_dim)
    Bh = Bm.reshape(B, S, s.n_groups, s.d_state)
    Ch = Cm.reshape(B, S, s.n_groups, s.d_state)
    chunk = min(s.chunk, S)
    y, _ = kops.ssd(xh, dt, A, Bh, Ch, chunk=chunk, impl=ssd_impl)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, d_inner).astype(x_in.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gn"], cfg.rms_eps)
    return x_in + dense(y, p["out_proj"])


# ---------------------------------------------------------------------------
# Decode (constant-size state)
# ---------------------------------------------------------------------------


def mamba_cache_shape(cfg: ArchConfig, batch: int) -> Dict[str, Any]:
    s, d_inner, H, conv_dim, _ = _dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, s.d_conv - 1, conv_dim), jnp.bfloat16),
        "ssm": jax.ShapeDtypeStruct(
            (batch, H, s.head_dim, s.d_state), jnp.float32
        ),
    }


def mamba_cache_spec(long_context: bool) -> Dict[str, Tuple]:
    # state is seq-independent; shard heads/channels over tp, batch over dp
    # (long-context decode has batch=1 — leave batch unsharded there)
    if long_context:
        return {"conv": (None, None, "tp"), "ssm": (None, "tp", None, None)}
    return {
        "conv": ("dp", None, "tp"),
        "ssm": ("dp", "tp", None, None),
    }


def mamba_decode_block(
    p: Dict[str, jnp.ndarray],
    x_in: jnp.ndarray,  # (B, 1, d)
    cache: Dict[str, jnp.ndarray],
    pos: jnp.ndarray,
    cfg: ArchConfig,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    s, d_inner, H, conv_dim, _ = _dims(cfg)
    B = x_in.shape[0]
    h = rms_norm(x_in, p["ln"], cfg.rms_eps)
    z = dense(h, p["z_proj"])[:, 0]
    xBC = jnp.concatenate(
        [dense(h, p["x_proj"]), dense(h, p["b_proj"]), dense(h, p["c_proj"])],
        axis=-1,
    )[:, 0]
    dt = dense(h, p["dt_proj"])[:, 0]
    conv_w = jnp.concatenate([p["conv_x_w"], p["conv_b_w"], p["conv_c_w"]], axis=1)
    conv_bias = jnp.concatenate([p["conv_x_b"], p["conv_b_b"], p["conv_c_b"]])

    conv_buf = cache["conv"]  # (B, d_conv-1, conv_dim)
    full = jnp.concatenate([conv_buf.astype(xBC.dtype), xBC[:, None, :]], axis=1)
    conv = (
        jnp.einsum("bkc,kc->bc", full, conv_w.astype(xBC.dtype))
        + conv_bias.astype(xBC.dtype)
    )
    xBC1 = jax.nn.silu(conv)
    new_conv_buf = full[:, 1:, :].astype(cache["conv"].dtype)

    xs, Bm, Cm = _split_xbc(xBC1, cfg)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, new_state = kref.ssd_decode_step(
        cache["ssm"],
        xs.reshape(B, H, s.head_dim),
        dtv,
        A,
        Bm.reshape(B, s.n_groups, s.d_state),
        Cm.reshape(B, s.n_groups, s.d_state),
    )
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xs.reshape(B, H, s.head_dim).astype(jnp.float32)
    y = y.reshape(B, d_inner).astype(x_in.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gn"], cfg.rms_eps)
    out = x_in + dense(y[:, None, :], p["out_proj"])
    return out, {"conv": new_conv_buf, "ssm": new_state}
