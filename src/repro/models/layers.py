"""Shared layers + parameter-definition infrastructure.

Parameters are declared once as ``PD(shape, spec, init)`` pytrees; the same
declaration yields real initialized arrays (smoke tests / examples),
ShapeDtypeStructs (dry-run lowering — no allocation), and logical
PartitionSpecs (translated to the physical mesh in ``repro.distributed``).

Logical sharding axes: "dp" (batch/data), "tp" (model/tensor).  Weight specs
follow the Megatron convention: column-parallel in-projections (out-dim tp),
row-parallel out-projections (in-dim tp), vocab-parallel embeddings, experts
expert-parallel over tp.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class PD:
    """Parameter definition: shape + logical partition spec + init scale."""

    shape: Tuple[int, ...]
    spec: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones
    scale: float = 0.02
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.spec), (self.shape, self.spec)


def init_tree(defs, rng: jax.Array):
    leaves, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, PD)
    )
    keys = jax.random.split(rng, len(leaves))
    out = []
    for pd, key in zip(leaves, keys):
        if pd.init == "zeros":
            out.append(jnp.zeros(pd.shape, pd.dtype))
        elif pd.init == "ones":
            out.append(jnp.ones(pd.shape, pd.dtype))
        else:
            out.append(
                (jax.random.normal(key, pd.shape, jnp.float32) * pd.scale).astype(
                    pd.dtype
                )
            )
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_tree(defs):
    return jax.tree_util.tree_map(
        lambda pd: jax.ShapeDtypeStruct(pd.shape, pd.dtype),
        defs,
        is_leaf=lambda x: isinstance(x, PD),
    )


def spec_tree(defs):
    return jax.tree_util.tree_map(
        lambda pd: pd.spec, defs, is_leaf=lambda x: isinstance(x, PD)
    )


def stack_defs(defs, n: int):
    """Stacked (scan) variant: prepend a replicated leading axis of size n."""
    return jax.tree_util.tree_map(
        lambda pd: PD((n,) + pd.shape, (None,) + pd.spec, pd.init, pd.scale, pd.dtype),
        defs,
        is_leaf=lambda x: isinstance(x, PD),
    )


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------

COMPUTE_DTYPE = jnp.bfloat16


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    return kops.rmsnorm(x, w, eps, impl="reference")


def dense(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("...d,df->...f", x, w.astype(x.dtype))


def rope(
    x: jnp.ndarray,  # (..., S, n, D) or (..., n, D) with positions scalar
    positions: jnp.ndarray,  # (S,) or scalar
    theta: float,
) -> jnp.ndarray:
    D = x.shape[-1]
    half = D // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    if x.ndim == angles.ndim + 2:  # (..., S, n, D): broadcast over heads
        sin, cos = sin[..., None, :], cos[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    return jnp.concatenate([xr1, xr2], axis=-1).astype(x.dtype)


def swiglu(x: jnp.ndarray, w_in: jnp.ndarray, w_out: jnp.ndarray) -> jnp.ndarray:
    """Fused gate+up projection: w_in: (d, 2*ff), w_out: (ff, d).
    Sharding left to GSPMD propagation from the column/row-parallel weights
    (§Perf: forcing the hidden over tp resharded the seq-sharded activations
    every layer — refuted)."""
    h = dense(x, w_in)
    gate, up = jnp.split(h, 2, axis=-1)
    return dense(jax.nn.silu(gate) * up, w_out)


# ---------------------------------------------------------------------------
# Dense MLP block
# ---------------------------------------------------------------------------


def mlp_defs(d: int, ff: int) -> Dict[str, PD]:
    return {
        "ln": PD((d,), (None,), init="ones"),
        "w_in": PD((d, 2 * ff), (None, "tp")),
        "w_out": PD((ff, d), ("tp", None)),
    }


def mlp_block(p: Dict[str, jnp.ndarray], x: jnp.ndarray, eps: float) -> jnp.ndarray:
    return x + swiglu(rms_norm(x, p["ln"], eps), p["w_in"], p["w_out"])
