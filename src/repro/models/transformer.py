"""Decoder-only LM assembled from the per-layer pattern (all LM families).

The layer stack is executed as ``lax.scan`` over *pattern periods* (e.g.
gemma3's (5×local + global), jamba's (4×mamba, attn, 3×mamba) with MoE every
other layer), with remainder layers unrolled in a tail — this keeps the HLO
O(period) instead of O(n_layers), which is what makes 62-72 layer configs
compile fast and keeps scan-carried activation sharding uniform.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed.sharding import constrain
from repro.models import attention as A
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.layers import (
    PD,
    abstract_tree,
    dense,
    init_tree,
    mlp_block,
    mlp_defs,
    rms_norm,
    spec_tree,
    stack_defs,
)

COMPUTE_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# Layer definitions from the pattern
# ---------------------------------------------------------------------------


def _layer_defs(cfg: ArchConfig, layer_idx: int) -> Dict[str, Any]:
    kind = cfg.pattern[layer_idx]
    defs: Dict[str, Any] = {}
    if kind == "mamba":
        defs["mixer"] = S.mamba_defs(cfg)
    else:
        defs["mixer"] = A.attn_defs(cfg)
    if cfg.moe is not None and cfg.moe_layer_mask()[layer_idx]:
        defs["ffn_moe"] = M.moe_defs(cfg)
    elif cfg.d_ff > 0:
        defs["ffn"] = mlp_defs(cfg.d_model, cfg.d_ff)
    return defs


def _segments(cfg: ArchConfig) -> Tuple[int, int, int]:
    p = max(1, cfg.scan_period)
    n_periods = cfg.n_layers // p
    rem = cfg.n_layers - n_periods * p
    # pattern must actually be periodic over the scanned prefix
    for i in range(n_periods * p):
        assert cfg.pattern[i] == cfg.pattern[i % p], (cfg.name, i)
    if cfg.moe is not None:
        assert p % cfg.moe.every == 0 or cfg.moe.every % p == 0 or cfg.moe.every == 1
    return p, n_periods, rem


def vocab_axis(V: int) -> Any:
    """Vocab-parallel only when the vocab divides the 16-wide model axis —
    whisper (51865) / internvl (92553) / mamba2 (50280) replicate instead."""
    return "tp" if V % 16 == 0 else None


def lm_param_defs(cfg: ArchConfig) -> Dict[str, Any]:
    p, n_periods, rem = _segments(cfg)
    d, V = cfg.d_model, cfg.vocab
    defs: Dict[str, Any] = {
        "embed": PD((V, d), (vocab_axis(V), None), scale=1.0 / (d ** 0.5)),
        "final_ln": PD((d,), (None,), init="ones"),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = PD((d, V), (None, vocab_axis(V)))
    if n_periods > 0:
        period_defs = {f"l{j}": _layer_defs(cfg, j) for j in range(p)}
        defs["scan"] = stack_defs(period_defs, n_periods)
    for i in range(rem):
        defs[f"tail{i}"] = _layer_defs(cfg, n_periods * p + i)
    return defs


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _block_fwd(
    lp: Dict[str, Any],
    x: jnp.ndarray,
    cfg: ArchConfig,
    kind: str,
    positions: jnp.ndarray,
    attn_impl: str,
) -> jnp.ndarray:
    if kind == "mamba":
        x = S.mamba_block(lp["mixer"], x, cfg, ssd_impl=attn_impl_to_ssd(attn_impl))
    else:
        x = A.attn_block(
            lp["mixer"], x, cfg, kind, positions=positions, attn_impl=attn_impl
        )
    if "ffn_moe" in lp:
        x = M.moe_block(lp["ffn_moe"], x, cfg)
    elif "ffn" in lp:
        x = mlp_block(lp["ffn"], x, cfg.rms_eps)
    # Megatron-SP hybrid (§Perf iteration 6): activations SEQUENCE-sharded
    # between layers (all-gather at block entry / reduce-scatter at exit),
    # heads/ffn sharded INSIDE blocks.  Replicated-interlayer (iteration 1)
    # turned every row-parallel output into a full-tensor all-reduce
    # (jamba: 1.6 TB/chip); plain seq-sharding without the internal head
    # constraints (baseline) pushed permutes inside the flash loops.
    return constrain(x, ("dp", "tp", None))


def attn_impl_to_ssd(attn_impl: str) -> str:
    return attn_impl  # same dispatch vocabulary


def lm_forward(
    params: Dict[str, Any],
    tokens: jnp.ndarray,  # (B, S) int32
    cfg: ArchConfig,
    *,
    attn_impl: str = "reference",
    remat: bool = True,
    prefix_embeds: Optional[jnp.ndarray] = None,  # (B, Sp, d) VLM patches
) -> jnp.ndarray:
    p, n_periods, rem = _segments(cfg)
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(COMPUTE_DTYPE)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(COMPUTE_DTYPE), x], axis=1)
    Sf = x.shape[1]
    positions = jnp.arange(Sf)
    x = constrain(x, ("dp", None, None))

    def period_fn(xc, pp):
        # NOTE: per-layer nested remat was tried (§Perf iteration 2) and
        # REFUTED — it re-ran each layer's collectives in the backward
        # (+23% collective bytes) without reducing live memory.
        for j in range(p):
            xc = _block_fwd(
                pp[f"l{j}"], xc, cfg, cfg.pattern[j], positions, attn_impl
            )
        return xc

    if n_periods > 0:
        body = jax.checkpoint(period_fn) if remat else period_fn

        def scan_fn(xc, pp):
            return body(xc, pp), None

        x, _ = jax.lax.scan(scan_fn, x, params["scan"])
    for i in range(rem):
        kind = cfg.pattern[n_periods * p + i]
        lp = params[f"tail{i}"]
        fn = functools.partial(
            _block_fwd, cfg=cfg, kind=kind, positions=positions, attn_impl=attn_impl
        )
        x = jax.checkpoint(fn)(lp, x) if remat else fn(lp, x)

    x = rms_norm(x, params["final_ln"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    return constrain(logits, ("dp", None, vocab_axis(cfg.vocab)))


def lm_loss(
    params: Dict[str, Any],
    batch: Dict[str, jnp.ndarray],
    cfg: ArchConfig,
    *,
    attn_impl: str = "reference",
    remat: bool = True,
) -> jnp.ndarray:
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    prefix = batch.get("prefix_embeds")  # VLM: projected patch embeddings
    logits = lm_forward(
        params, inputs, cfg, attn_impl=attn_impl, remat=remat, prefix_embeds=prefix
    )
    if prefix is not None:
        logits = logits[:, prefix.shape[1]:]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# Decode (serve_step): one token against stacked KV/SSM caches
# ---------------------------------------------------------------------------


def lm_cache_shapes(cfg: ArchConfig, batch: int, seq: int) -> Dict[str, Any]:
    p, n_periods, rem = _segments(cfg)

    def layer_cache(kind):
        if kind == "mamba":
            return S.mamba_cache_shape(cfg, batch)
        return A.attn_cache_shape(cfg, batch, seq)

    out: Dict[str, Any] = {}
    if n_periods > 0:
        per = {f"l{j}": layer_cache(cfg.pattern[j]) for j in range(p)}
        out["scan"] = jax.tree_util.tree_map(
            lambda sds: jax.ShapeDtypeStruct((n_periods,) + sds.shape, sds.dtype), per
        )
    for i in range(rem):
        out[f"tail{i}"] = layer_cache(cfg.pattern[n_periods * p + i])
    return out


def lm_cache_specs(cfg: ArchConfig, long_context: bool) -> Dict[str, Any]:
    p, n_periods, rem = _segments(cfg)

    def layer_spec(kind):
        if kind == "mamba":
            return S.mamba_cache_spec(long_context)
        return A.attn_cache_spec(long_context)

    out: Dict[str, Any] = {}
    if n_periods > 0:
        per = {f"l{j}": layer_spec(cfg.pattern[j]) for j in range(p)}
        out["scan"] = jax.tree_util.tree_map(
            lambda s: (None,) + s, per, is_leaf=lambda s: isinstance(s, tuple)
        )
    for i in range(rem):
        out[f"tail{i}"] = layer_spec(cfg.pattern[n_periods * p + i])
    return out


def _block_decode(
    lp: Dict[str, Any],
    cache: Dict[str, Any],
    x: jnp.ndarray,
    pos: jnp.ndarray,
    cfg: ArchConfig,
    kind: str,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    if kind == "mamba":
        x, new_cache = S.mamba_decode_block(lp["mixer"], x, cache, pos, cfg)
    else:
        x, new_cache = A.attn_decode_block(lp["mixer"], x, cache, pos, cfg, kind)
    if "ffn_moe" in lp:
        x = M.moe_block(lp["ffn_moe"], x, cfg)
    elif "ffn" in lp:
        x = mlp_block(lp["ffn"], x, cfg.rms_eps)
    return x, new_cache


def lm_decode_step(
    params: Dict[str, Any],
    caches: Dict[str, Any],
    token: jnp.ndarray,  # (B,) int32
    pos: jnp.ndarray,    # scalar int32
    cfg: ArchConfig,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One decode step: returns (logits (B, V), new caches)."""
    p, n_periods, rem = _segments(cfg)
    x = jnp.take(params["embed"], token, axis=0)[:, None, :].astype(COMPUTE_DTYPE)

    new_caches: Dict[str, Any] = {}
    if n_periods > 0:

        def period_step(xc, inp):
            pp, cc = inp
            new_cc = {}
            for j in range(p):
                xc, new_cc[f"l{j}"] = _block_decode(
                    pp[f"l{j}"], cc[f"l{j}"], xc, pos, cfg, cfg.pattern[j]
                )
            return xc, new_cc

        x, new_caches["scan"] = jax.lax.scan(
            period_step, x, (params["scan"], caches["scan"])
        )
    for i in range(rem):
        kind = cfg.pattern[n_periods * p + i]
        x, new_caches[f"tail{i}"] = _block_decode(
            params[f"tail{i}"], caches[f"tail{i}"], x, pos, cfg, kind
        )

    x = rms_norm(x, params["final_ln"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))[:, 0]
    return logits.astype(jnp.float32), new_caches


# ---------------------------------------------------------------------------
# Prefill (examples/serving): full forward that also fills the caches
# ---------------------------------------------------------------------------


def lm_prefill(
    params: Dict[str, Any],
    tokens: jnp.ndarray,  # (B, S)
    cache_len: int,
    cfg: ArchConfig,
    *,
    attn_impl: str = "reference",
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Sequential decode-based prefill (simple + exact; examples only)."""
    B, S = tokens.shape
    caches = jax.tree_util.tree_map(
        lambda sds: jnp.zeros(sds.shape, sds.dtype),
        lm_cache_shapes(cfg, B, cache_len),
    )

    def step(carry, t):
        caches, _ = carry
        logits, caches = lm_decode_step(params, caches, tokens[:, t], t, cfg)
        return (caches, logits), None

    (caches, logits), _ = jax.lax.scan(
        step, (caches, jnp.zeros((B, cfg.vocab), jnp.float32)), jnp.arange(S)
    )
    return logits, caches
