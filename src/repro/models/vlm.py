"""VLM wrapper (internvl2): ViT-frontend STUB + projector + LM backbone.

``input_specs`` provides precomputed patch embeddings (B, n_patches,
d_vision); the projector maps them to d_model and they are prepended to the
token embeddings (early-fusion prefix).  Loss is computed on text positions.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import PD, dense
from repro.models.transformer import lm_loss, lm_param_defs

COMPUTE_DTYPE = jnp.bfloat16


def vlm_param_defs(cfg: ArchConfig) -> Dict[str, Any]:
    defs = lm_param_defs(cfg)
    defs["vision_proj"] = PD((cfg.vision.d_vision, cfg.d_model), (None, "tp"))
    return defs


def vlm_loss(
    params: Dict[str, Any],
    batch: Dict[str, jnp.ndarray],
    cfg: ArchConfig,
    *,
    attn_impl: str = "reference",
    remat: bool = True,
) -> jnp.ndarray:
    prefix = dense(batch["patch_embeds"].astype(COMPUTE_DTYPE), params["vision_proj"])
    lm_batch = {"tokens": batch["tokens"], "prefix_embeds": prefix}
    return lm_loss(params, lm_batch, cfg, attn_impl=attn_impl, remat=remat)
