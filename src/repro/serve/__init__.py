from repro.serve.decode import greedy_generate, init_caches

__all__ = ["greedy_generate", "init_caches"]
