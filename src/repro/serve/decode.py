"""Batched serving: prefill + greedy decode against the KV/SSM caches."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec as E
from repro.models import transformer as T
from repro.models.registry import Model


def init_caches(model: Model, batch: int, cache_len: int):
    cfg = model.cfg
    shapes = (
        E.encdec_cache_shapes(cfg, batch, cache_len)
        if cfg.family == "audio"
        else T.lm_cache_shapes(cfg, batch, cache_len)
    )
    return jax.tree_util.tree_map(
        lambda sds: jnp.zeros(sds.shape, sds.dtype), shapes
    )


def greedy_generate(
    model: Model,
    params,
    prompt: jnp.ndarray,  # (B, S0) int32
    *,
    max_new_tokens: int,
    cache_len: Optional[int] = None,
) -> jnp.ndarray:
    """Prefill the prompt token-by-token then decode greedily (jit'd step)."""
    B, S0 = prompt.shape
    cache_len = cache_len or (S0 + max_new_tokens)
    caches = init_caches(model, B, cache_len)
    step = jax.jit(
        lambda p, c, t, pos: model.decode_step(p, c, t, pos)
    )

    logits = None
    for t in range(S0):
        logits, caches = step(params, caches, prompt[:, t], jnp.asarray(t))
    out = [jnp.argmax(logits, axis=-1)]
    for i in range(max_new_tokens - 1):
        logits, caches = step(
            params, caches, out[-1].astype(jnp.int32), jnp.asarray(S0 + i)
        )
        out.append(jnp.argmax(logits, axis=-1))
    return jnp.stack(out, axis=1)
