"""Pallas TPU flash attention (forward) — GQA, causal/sliding/chunked.

TPU-native design (HARDWARE ADAPTATION notes):
  * grid = (B, H, nQ, nK) with the KV axis innermost: TPU grids execute
    sequentially on a core, so fp32 VMEM scratch (acc, m, l) carries the
    online-softmax state across KV steps — the TPU analogue of a CUDA
    thread-block loop with shared-memory accumulators (no warp shuffles).
  * BlockSpecs tile Q/K/V into (q_block, D)/(kv_block, D) VMEM tiles with
    MXU-aligned 128-multiples; GQA is folded into the K/V index_map
    (kv head = q head // group), so no KV duplication in HBM or VMEM.
  * causal / sliding-window / chunked-local masks are built from iota over
    block-local positions; fully-masked KV blocks are SKIPPED via
    ``@pl.when`` (grid still visits them, but no MXU work is issued —
    this is where the kernel beats the XLA lowering, which cannot skip).

The backward pass uses the blocked jnp flash VJP (ref.py), which the SPMD
partitioner handles well; a Pallas backward is a recorded follow-up.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    chunk: Optional[int],
    q_block: int,
    kv_block: int,
    n_kv: int,
    seq_len: int,
    q_offset: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * q_block + q_offset
    k_start = ki * kv_block

    # block-level skip: is any (q, k) pair in this tile unmasked?
    needed = jnp.bool_(True)
    if causal:
        needed &= k_start <= q_start + q_block - 1
    if window is not None:
        needed &= k_start + kv_block - 1 > q_start - window
    if chunk is not None:
        needed &= (k_start // chunk) <= ((q_start + q_block - 1) // chunk)
        needed &= (k_start + kv_block - 1) // chunk >= (q_start // chunk)
    needed &= k_start < seq_len

    @pl.when(needed)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)      # (q_block, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # (kv_block, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                       # (q_block, kv_block)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1)
        mask = kpos < seq_len
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        if chunk is not None:
            mask &= (kpos // chunk) == (qpos // chunk)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # (B, S, H, D)
    k: jnp.ndarray,  # (B, T, KV, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    chunk: Optional[int] = None,
    q_offset: int = 0,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_block = min(q_block, S)
    kv_block = min(kv_block, T)
    nq = (S + q_block - 1) // q_block
    nk = (T + kv_block - 1) // kv_block
    pad_q = nq * q_block - S
    pad_k = nk * kv_block - T
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    kernel = functools.partial(
        _fa_kernel,
        scale=1.0 / (D ** 0.5),
        causal=causal,
        window=window,
        chunk=chunk,
        q_block=q_block,
        kv_block=kv_block,
        n_kv=nk,
        seq_len=T,
        q_offset=q_offset,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, kv_block, 1, D), lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, kv_block, 1, D), lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nq * q_block, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, D), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]
