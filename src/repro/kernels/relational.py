"""Fused elementwise kernels for the relational data plane.

The jax plane's filter/project kernels are pure elementwise programs
(adds, compares, boolean combine — the multiply program is jitted
separately; see ``engine/plane/jax_plane.py``).  This module gives them
the same dispatch policy as the other repo kernels (``kernels/ops.py``):

  * "auto"      — Pallas kernel on TPU backends *when the roofline says
                  the program is bandwidth-bound* (elementwise relational
                  bodies essentially always are: zero dot-flops, pure
                  streaming), jitted jnp elsewhere.
  * "pallas"    — force the Pallas lowering (TPU).
  * "interpret" — Pallas kernel body in interpret mode (CPU tests).
  * "reference" — plain ``jax.jit`` of the body.

The Pallas lowering pads each 1-D operand to a multiple of
``block_rows * lane`` (8×128 — the float32 TPU tile), reshapes to
``(rows, lane)`` and runs an elementwise grid over row blocks.  Bodies
must be elementwise (no reductions, no cross-row communication) so block
decomposition is trivially exact; exactness of the *values* is the
plane's concern (its bodies contain no multiplies, so there is nothing
for XLA to FMA-contract).
"""

from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

_BLOCK_ROWS = 8
_LANE = 128


def pow2_bucket(n: int) -> int:
    """Smallest power of two >= n (>= 1).  Operands are zero-padded to
    bucket sizes before jit so compiled kernels are reused across row
    counts (jit specializes per shape; filter selectivity would otherwise
    force a recompile on every chain execution)."""
    return 1 << max(0, int(n - 1).bit_length()) if n > 1 else 1


def _pad_to(a, b: int):
    n = int(a.shape[0])
    if n == b:
        return a
    pad_val = False if a.dtype == jnp.bool_ else 0
    return jnp.pad(a, (0, b - n), constant_values=pad_val)


def _default_impl(body: Callable, arrs: Sequence) -> str:
    try:
        plat = jax.default_backend()
    except Exception:  # pragma: no cover
        plat = "cpu"
    if plat != "tpu":
        return "reference"
    try:
        from repro.launch.roofline import is_bandwidth_bound

        return "pallas" if is_bandwidth_bound(body, *arrs) else "reference"
    except Exception:  # pragma: no cover - analysis failure = safe default
        return "reference"


def build_elementwise(body: Callable, *, impl: str = "auto") -> Callable:
    """A cached callable running ``body`` under the dispatch policy.

    ``body`` maps 1-D arrays to a 1-D array or tuple of 1-D arrays, all of
    one common length.  The returned callable accepts numpy or jax arrays
    and returns numpy.  Dispatch for ``"auto"`` is resolved once, on the
    first call (the roofline check needs sample operands); the resolution
    is idempotent, so racing first calls are benign.
    """
    state: dict = {}

    def call(*arrs):
        fn = state.get("fn")
        if fn is None:
            mode = impl if impl != "auto" else _default_impl(body, arrs)
            if mode in ("pallas", "interpret"):
                interp = mode == "interpret"

                def fn(*xs):
                    return _elementwise_pallas(body, xs, interpret=interp)

            else:
                jitted = jax.jit(body)

                # zero-pad to power-of-two buckets: bodies are elementwise
                # (a pad lane never influences a real lane), so slicing the
                # outputs back to n is exact, and the jit cache is hit for
                # every row count in the same bucket
                def fn(*xs):
                    xs = [jnp.asarray(x) for x in xs]
                    n = int(xs[0].shape[0])
                    b = pow2_bucket(n)
                    out = jitted(*[_pad_to(x, b) for x in xs])
                    if isinstance(out, (tuple, list)):
                        return tuple(np.asarray(o)[:n] for o in out)
                    return np.asarray(out)[:n]

            state["fn"] = fn
        return fn(*arrs)

    return call


def _elementwise_pallas(
    body: Callable,
    arrays: Sequence,
    *,
    interpret: bool,
    block_rows: int = _BLOCK_ROWS,
    lane: int = _LANE,
):
    from jax.experimental import pallas as pl

    arrays = [jnp.asarray(a) for a in arrays]
    n = int(arrays[0].shape[0])
    tile = block_rows * lane
    m = max(1, -(-n // tile))  # ceil; one padding block for n == 0
    padded = m * tile

    blocks = []
    for a in arrays:
        pad_val = False if a.dtype == jnp.bool_ else 0
        a = jnp.pad(a, (0, padded - n), constant_values=pad_val)
        blocks.append(a.reshape(m * block_rows, lane))

    out_shape = jax.eval_shape(
        body,
        *[
            jax.ShapeDtypeStruct((block_rows, lane), a.dtype)
            for a in blocks
        ],
    )
    single = not isinstance(out_shape, (tuple, list))
    outs = (out_shape,) if single else tuple(out_shape)
    n_in = len(blocks)

    def kernel(*refs):
        ins, out_refs = refs[:n_in], refs[n_in:]
        res = body(*[r[...] for r in ins])
        res = (res,) if not isinstance(res, (tuple, list)) else tuple(res)
        for o, r in zip(out_refs, res):
            o[...] = r

    spec = pl.BlockSpec((block_rows, lane), lambda i: (i, 0))
    result = pl.pallas_call(
        kernel,
        grid=(m,),
        in_specs=[spec for _ in blocks],
        out_specs=tuple(spec for _ in outs),
        out_shape=tuple(
            jax.ShapeDtypeStruct((m * block_rows, lane), o.dtype)
            for o in outs
        ),
        interpret=interpret,
    )(*blocks)

    unpacked = tuple(np.asarray(r).reshape(-1)[:n] for r in result)
    return unpacked[0] if single else unpacked
