"""Pure-jnp reference oracles for every Pallas kernel.

These are also the default compute path on non-TPU backends (and for the
multi-pod dry-run, where the roofline is derived from their HLO).  They are
written flash-style — blocked online-softmax attention, chunked SSD — so the
*memory* roofline matches what the Pallas kernels claim on TPU.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Attention (GQA, causal / sliding-window / chunked-local), flash-style
# ---------------------------------------------------------------------------


def attention_reference(
    q: jnp.ndarray,  # (B, S, H, D)
    k: jnp.ndarray,  # (B, T, KV, D)
    v: jnp.ndarray,  # (B, T, KV, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,   # sliding window (attend to last `window`)
    chunk: Optional[int] = None,    # chunked-local (attend within chunk)
    q_offset: int = 0,              # absolute position of q[0] (decode/prefill)
) -> jnp.ndarray:
    """Naive O(S·T) attention — the oracle for tests. fp32 softmax."""
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, D)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.asarray(D, jnp.float32))
    qpos = jnp.arange(S) + q_offset
    kpos = jnp.arange(T)
    mask = jnp.ones((S, T), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    if chunk is not None:
        mask &= (kpos[None, :] // chunk) == (qpos[:, None] // chunk)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)


def _block_bias(qpos, kpos, T, causal, window, chunk):
    """Additive mask bias for a (q_block, kv_block) tile, built from the
    position vectors (never materialized across blocks)."""
    bias = jnp.zeros((qpos.shape[0], kpos.shape[0]), jnp.float32)
    if causal:
        bias = jnp.where(kpos[None, :] <= qpos[:, None], bias, NEG_INF)
    if window is not None:
        bias = jnp.where(kpos[None, :] > qpos[:, None] - window, bias, NEG_INF)
    if chunk is not None:
        bias = jnp.where(
            (kpos[None, :] // chunk) == (qpos[:, None] // chunk), bias, NEG_INF
        )
    return jnp.where((kpos < T)[None, :], bias, NEG_INF)


def flash_attention_jnp(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    chunk: Optional[int] = None,
    q_block: int = 512,
    kv_block: int = 512,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Blocked online-softmax attention with a flash-style custom VJP.

    Forward saves only (q, k, v, out, lse); the backward recomputes block
    probabilities — O(S·block) live memory in both passes, matching the
    Pallas kernel's VMEM story.  Without the custom VJP, autodiff of the KV
    scan stacks per-block probabilities (observed 8.6 GB/layer/device on the
    dry-run — EXPERIMENTS.md §Perf iteration 1)."""
    return _flash(q, k, v, causal, window, chunk, q_block, kv_block, q_offset)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, window, chunk, q_block, kv_block, q_offset):
    out, _ = _flash_fwd_impl(
        q, k, v, causal, window, chunk, q_block, kv_block, q_offset
    )
    return out


def _flash_fwd_rule(q, k, v, causal, window, chunk, q_block, kv_block, q_offset):
    out, lse = _flash_fwd_impl(
        q, k, v, causal, window, chunk, q_block, kv_block, q_offset
    )
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(causal, window, chunk, q_block, kv_block, q_offset, res, g):
    q, k, v, out, lse = res
    dq, dk, dv = _flash_bwd_impl(
        q, k, v, out, lse, g, causal, window, chunk, q_block, kv_block, q_offset
    )
    return dq, dk, dv


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def _flash_fwd_impl(q, k, v, causal, window, chunk, q_block, kv_block, q_offset):
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_block = min(q_block, S)
    kv_block = min(kv_block, T)
    nq = (S + q_block - 1) // q_block
    nk = (T + kv_block - 1) // kv_block
    pad_q = nq * q_block - S
    pad_k = nk * kv_block - T
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    qb = q.reshape(B, nq, q_block, KV, G, D).astype(jnp.float32)
    kb = k.reshape(B, nk, kv_block, KV, D).astype(jnp.float32)
    vb = v.reshape(B, nk, kv_block, KV, D).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))

    def per_q_block(qi, q_tile):
        # q_tile: (B, q_block, KV, G, D).  NOTE: block indices are
        # loop-CARRIED counters, not scan xs — if kpos/qpos came from
        # iota xs, XLA hoists every block's mask into one giant stacked
        # pred buffer (observed: 2.1 GB/layer on the 512-dev dry-run).
        qpos = qi * q_block + jnp.arange(q_block) + q_offset

        def kv_step(carry, inp):
            acc, m, l, ki = carry
            k_tile, v_tile = inp
            kpos = ki * kv_block + jnp.arange(kv_block)
            s = jnp.einsum("bqkgd,btkd->bkgqt", q_tile, k_tile) * scale
            bias = _block_bias(qpos, kpos, T, causal, window, chunk)
            s = s + bias[None, None, None]
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p, v_tile
            )
            return (acc_new, m_new, l_new, ki + 1), None

        acc0 = jnp.zeros((B, KV, G, q_block, D), jnp.float32)
        m0 = jnp.full((B, KV, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_block), jnp.float32)
        (acc, m, l, _), _ = jax.lax.scan(
            kv_step,
            (acc0, m0, l0, jnp.zeros((), jnp.int32)),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1)),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        # (B, KV, G, q_block, D) -> (B, q_block, KV, G, D)
        return out.transpose(0, 3, 1, 2, 4), lse.transpose(0, 3, 1, 2)

    def map_body(carry, q_tile):
        qi = carry
        o, lse = per_q_block(qi, q_tile)
        return qi + 1, (o, lse)

    _, (outs, lses) = jax.lax.scan(
        map_body, jnp.zeros((), jnp.int32), qb.swapaxes(0, 1)
    )  # (nq, B, q_block, KV, G, D) / (nq, B, q_block, KV, G)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * q_block, H, D)
    lse = lses.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_block, H)
    return out[:, :S].astype(q.dtype), lse[:, :S]


def _flash_bwd_impl(
    q, k, v, out, lse, g, causal, window, chunk, q_block, kv_block, q_offset
):
    """Flash backward: recompute block probabilities from saved lse.

    dV = Σ_q pᵀ g;  dP = g Vᵀ;  dS = p ∘ (dP − δ) with δ = Σ_d g·out;
    dQ = dS K;  dK = dSᵀ Q.  Scans q-blocks (carrying dK/dV accumulators)
    inside a scan over kv-blocks — O(blocks) live memory.
    """
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_block = min(q_block, S)
    kv_block = min(kv_block, T)
    nq = (S + q_block - 1) // q_block
    nk = (T + kv_block - 1) // kv_block
    pad_q = nq * q_block - S
    pad_k = nk * kv_block - T
    f32 = jnp.float32

    def padq(x):
        return jnp.pad(x, ((0, 0), (0, pad_q)) + ((0, 0),) * (x.ndim - 2)) if pad_q else x

    def padk(x):
        return jnp.pad(x, ((0, 0), (0, pad_k)) + ((0, 0),) * (x.ndim - 2)) if pad_k else x

    qb = padq(q).reshape(B, nq, q_block, KV, G, D).astype(f32)
    ob = padq(out).reshape(B, nq, q_block, KV, G, D).astype(f32)
    gb = padq(g).reshape(B, nq, q_block, KV, G, D).astype(f32)
    lseb = padq(lse).reshape(B, nq, q_block, KV, G)
    kb = padk(k).reshape(B, nk, kv_block, KV, D).astype(f32)
    vb = padk(v).reshape(B, nk, kv_block, KV, D).astype(f32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, f32))
    delta = jnp.sum(ob * gb, axis=-1)  # (B, nq, q_block, KV, G)

    def kv_step(ki, k_tile, v_tile):
        kpos = ki * kv_block + jnp.arange(kv_block)

        def q_step(carry, inp):
            dk_acc, dv_acc, qi = carry
            q_tile, g_tile, l_tile, d_tile = inp
            qpos = qi * q_block + jnp.arange(q_block) + q_offset
            s = jnp.einsum("bqkgd,btkd->bkgqt", q_tile, k_tile) * scale
            bias = _block_bias(qpos, kpos, T, causal, window, chunk)
            p = jnp.exp(s + bias[None, None, None] - l_tile.transpose(0, 2, 3, 1)[..., None])
            dv_acc = dv_acc + jnp.einsum("bkgqt,bqkgd->btkd", p, g_tile)
            dp = jnp.einsum("bqkgd,btkd->bkgqt", g_tile, v_tile)
            ds = p * (dp - d_tile.transpose(0, 2, 3, 1)[..., None]) * scale
            dq_blk = jnp.einsum("bkgqt,btkd->bqkgd", ds, k_tile)
            dk_acc = dk_acc + jnp.einsum("bkgqt,bqkgd->btkd", ds, q_tile)
            return (dk_acc, dv_acc, qi + 1), dq_blk

        dk0 = jnp.zeros((B, kv_block, KV, D), f32)
        dv0 = jnp.zeros((B, kv_block, KV, D), f32)
        (dk_t, dv_t, _), dq_blocks = jax.lax.scan(
            q_step,
            (dk0, dv0, jnp.zeros((), jnp.int32)),
            (
                qb.swapaxes(0, 1),
                gb.swapaxes(0, 1),
                lseb.swapaxes(0, 1),
                delta.swapaxes(0, 1),
            ),
        )
        return dk_t, dv_t, dq_blocks.swapaxes(0, 1)  # (B, nq, qb, KV, G, D)

    def kv_loop(carry, inp):
        dq_acc, ki = carry
        k_tile, v_tile = inp
        dk_t, dv_t, dq_contrib = kv_step(ki, k_tile, v_tile)
        return (dq_acc + dq_contrib, ki + 1), (dk_t, dv_t)

    dq0 = jnp.zeros((B, nq, q_block, KV, G, D), f32)
    (dq_acc, _), (dk_all, dv_all) = jax.lax.scan(
        kv_loop,
        (dq0, jnp.zeros((), jnp.int32)),
        (kb.swapaxes(0, 1), vb.swapaxes(0, 1)),
    )
    dq = dq_acc.reshape(B, nq * q_block, H, D)[:, :S].astype(q.dtype)
    dk = dk_all.transpose(1, 0, 2, 3, 4).reshape(B, nk * kv_block, KV, D)[:, :T].astype(k.dtype)
    dv = dv_all.transpose(1, 0, 2, 3, 4).reshape(B, nk * kv_block, KV, D)[:, :T].astype(v.dtype)
    return dq, dk, dv


def decode_attention_reference(
    q: jnp.ndarray,        # (B, H, D) single new token
    k_cache: jnp.ndarray,  # (B, T, KV, D)
    v_cache: jnp.ndarray,  # (B, T, KV, D)
    pos: jnp.ndarray,      # scalar int32: index of the new token
    *,
    window: Optional[int] = None,
    chunk: Optional[int] = None,
) -> jnp.ndarray:
    B, H, D = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    # no fp32 materialization of the cache: bf16 reads, fp32 accumulation
    qg = q.reshape(B, KV, G, D).astype(k_cache.dtype)
    scores = jnp.einsum(
        "bkgd,btkd->bkgt", qg, k_cache, preferred_element_type=jnp.float32
    )
    scores = scores / jnp.sqrt(jnp.asarray(D, jnp.float32))
    kpos = jnp.arange(T)
    mask = kpos <= pos
    if window is not None:
        mask &= kpos > pos - window
    if chunk is not None:
        mask &= (kpos // chunk) == (pos // chunk)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bkgt,btkd->bkgd",
        probs.astype(k_cache.dtype),
        v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality) — chunked reference
# ---------------------------------------------------------------------------


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k] for j<i,
    -inf above the diagonal (no contribution)."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    idx = jnp.arange(L)
    mask = idx[:, None] >= idx[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_reference(
    x: jnp.ndarray,    # (B, L, H, P) inputs per head
    dt: jnp.ndarray,   # (B, L, H)    softplus'd step sizes
    A: jnp.ndarray,    # (H,)         negative decay rates
    Bm: jnp.ndarray,   # (B, L, G, N) input projections
    Cm: jnp.ndarray,   # (B, L, G, N) output projections
    *,
    chunk: int = 256,
    initial_state: Optional[jnp.ndarray] = None,  # (B, H, P, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD (Mamba-2, arXiv:2405.21060 Listing 1 adapted to jnp).

    Returns (y: (B, L, H, P), final_state: (B, H, P, N)).
    """
    Bsz, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    rep = H // G

    f32 = jnp.float32
    x_ = x.reshape(Bsz, nc, chunk, H, P).astype(f32)
    dt_ = dt.reshape(Bsz, nc, chunk, H).astype(f32)
    B_ = Bm.reshape(Bsz, nc, chunk, G, N).astype(f32)
    C_ = Cm.reshape(Bsz, nc, chunk, G, N).astype(f32)

    dA = dt_ * A.astype(f32)[None, None, None, :]          # (B, nc, c, H)
    dA_cs = jnp.cumsum(dA, axis=2)                          # within-chunk cumsum

    # 1) intra-chunk (diagonal blocks): Y_diag = (C Bᵀ ∘ L) · (dt·x)
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))       # (B, nc, H, c, c)
    CB = jnp.einsum("bzcgn,bzsgn->bzgcs", C_, B_)           # (B, nc, G, c, c)
    CB = jnp.repeat(CB, rep, axis=2)                        # (B, nc, H, c, c)
    dtx = x_ * dt_[..., None]                               # (B, nc, c, H, P)
    y_diag = jnp.einsum("bzhcs,bzshp->bzchp", CB * Lmat, dtx)

    # 2) chunk-final states: S_z = Σ_s exp(dA_cs[end]-dA_cs[s]) B_s ⊗ dtx_s
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)     # (B, nc, c, H)
    Bh = jnp.repeat(B_, rep, axis=3)                        # (B, nc, c, H, N)
    states = jnp.einsum("bzshn,bzshp->bzhpn", Bh * decay_to_end[..., None], dtx)

    # 3) inter-chunk recurrence: carry running state across chunks
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])               # (B, nc, H)

    def scan_fn(carry, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit state *entering* this chunk

    init = (
        initial_state.astype(f32)
        if initial_state is not None
        else jnp.zeros((Bsz, H, P, N), f32)
    )
    final_state, prev_states = jax.lax.scan(
        scan_fn,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)      # (B, nc, H, P, N)

    # 4) inter-chunk output: Y_off = (C_s · S_prev) * exp(dA_cs[s])
    state_decay = jnp.exp(dA_cs)                            # (B, nc, c, H)
    Ch = jnp.repeat(C_, rep, axis=3)                        # (B, nc, c, H, N)
    y_off = jnp.einsum("bzchn,bzhpn->bzchp", Ch, prev_states) * state_decay[..., None]

    y = (y_diag + y_off).reshape(Bsz, L, H, P)
    return y.astype(x.dtype), final_state


def ssd_decode_step(
    state: jnp.ndarray,  # (B, H, P, N)
    x: jnp.ndarray,      # (B, H, P)
    dt: jnp.ndarray,     # (B, H)
    A: jnp.ndarray,      # (H,)
    Bm: jnp.ndarray,     # (B, G, N)
    Cm: jnp.ndarray,     # (B, G, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token SSM recurrence: h ← h·exp(dt·A) + dt·(B ⊗ x); y = C·h."""
    H = x.shape[1]
    G = Bm.shape[1]
    rep = H // G
    f32 = jnp.float32
    dA = jnp.exp(dt.astype(f32) * A.astype(f32)[None, :])     # (B, H)
    Bh = jnp.repeat(Bm.astype(f32), rep, axis=1)              # (B, H, N)
    Ch = jnp.repeat(Cm.astype(f32), rep, axis=1)
    dBx = jnp.einsum("bhn,bhp->bhpn", Bh, x.astype(f32) * dt.astype(f32)[..., None])
    new_state = state.astype(f32) * dA[..., None, None] + dBx
    y = jnp.einsum("bhn,bhpn->bhp", Ch, new_state)
    return y.astype(x.dtype), new_state.astype(state.dtype)


# ---------------------------------------------------------------------------
# Fused RMSNorm (kernel hot-spot #3)
# ---------------------------------------------------------------------------


def rmsnorm_reference(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)
