"""Pallas TPU Mamba-2 SSD kernel (chunked state-space duality, fwd).

TPU adaptation of the paper's (arXiv:2405.21060) GPU kernel:
  * grid = (B, H, n_chunks), chunk axis innermost: the (P, N) running SSM
    state lives in fp32 VMEM scratch and carries across chunk steps —
    the sequential-grid analogue of the GPU kernel's inter-block state
    passing (which needs split-K + global-memory semaphores on CUDA).
  * per chunk, the quadratic intra-chunk term (C Bᵀ ∘ L)(dt·x) uses the MXU
    via (c×N)(N×c) and (c×c)(c×P) dot_generals; decay matrices come from a
    cumulative-sum segsum built with iota comparisons in-register.
  * B/C group indexing (G groups, H heads) is folded into the index_map
    (g = h // (H // G)) like GQA in the attention kernel.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,    # (1, c, 1, P)
    dt_ref,   # (1, c, 1)
    A_ref,    # (1,)
    B_ref,    # (1, c, 1, N)
    C_ref,    # (1, c, 1, N)
    y_ref,    # (1, c, 1, P)
    st_ref,   # (1, 1, P, N) final state out
    state_ref,  # VMEM scratch (P, N) f32
    *,
    n_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # (c, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # (c,)
    A = A_ref[0].astype(jnp.float32)               # scalar
    Bm = B_ref[0, :, 0, :].astype(jnp.float32)     # (c, N)
    Cm = C_ref[0, :, 0, :].astype(jnp.float32)     # (c, N)

    c = x.shape[0]
    dA = dt * A                                     # (c,)
    cs = jnp.cumsum(dA)                             # within-chunk cumsum

    # intra-chunk: L[i,j] = exp(cs_i - cs_j) for j<=i (dA_j included via dtx)
    ii = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    L = jnp.where(ii >= jj, jnp.exp(cs[:, None] - cs[None, :]), 0.0)
    CB = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (c, c)
    dtx = x * dt[:, None]                           # (c, P)
    y_diag = jax.lax.dot_general(
        CB * L, dtx, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # inter-chunk: y_off = (C · state_prev^T) * exp(cs)
    state = state_ref[...]                          # (P, N)
    y_off = jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(cs)[:, None]                        # (c, P)

    y_ref[0, :, 0, :] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: S ← S·exp(Σ dA) + Σ_s exp(cs_end - cs_s) dtx_s ⊗ B_s
    decay_to_end = jnp.exp(cs[-1] - cs)             # (c,)
    contrib = jax.lax.dot_general(
        dtx * decay_to_end[:, None],
        Bm,
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                               # (P, N)
    state_ref[...] = state * jnp.exp(cs[-1]) + contrib

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        st_ref[0, 0, :, :] = state_ref[...].astype(st_ref.dtype)


def ssd_pallas(
    x: jnp.ndarray,    # (B, L, H, P)
    dt: jnp.ndarray,   # (B, L, H)
    A: jnp.ndarray,    # (H,)
    Bm: jnp.ndarray,   # (B, L, G, N)
    Cm: jnp.ndarray,   # (B, L, G, N)
    *,
    chunk: int = 256,
    initial_state: Optional[jnp.ndarray] = None,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if initial_state is not None:
        # kernel carries zero-initialized state; nonzero init via reference
        from repro.kernels import ref

        return ref.ssd_reference(
            x, dt, A, Bm, Cm, chunk=chunk, initial_state=initial_state
        )
    B, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk

    kernel = functools.partial(_ssd_kernel, n_chunks=nc)
    y, st = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, ci: (b, ci, h)),
            pl.BlockSpec((1,), lambda b, h, ci: (h,)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, ci: (b, ci, h // rep, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, ci: (b, ci, h // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y, st
