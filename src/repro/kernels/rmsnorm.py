"""Pallas TPU fused RMSNorm kernel (rows × feature tiles, fp32 reduction)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rms_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)              # (rows, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w[None, :]).astype(o_ref.dtype)


def rmsnorm_pallas(
    x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5, *, rows_block: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    orig_shape = x.shape
    D = x.shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    rows_block = min(rows_block, R)
    nr = (R + rows_block - 1) // rows_block
    pad = nr * rows_block - R
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rms_kernel, eps=eps),
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((rows_block, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows_block, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nr * rows_block, D), x.dtype),
        interpret=interpret,
    )(xf, w)
    return out[:R].reshape(orig_shape)
