"""Jit'd public wrappers for the compute hot-spots.

Dispatch policy (``impl``):
  * "auto"      — Pallas kernel on TPU backends, reference elsewhere.
  * "pallas"    — force the Pallas kernel (TPU lowering).
  * "interpret" — Pallas kernel body executed in interpret mode (CPU tests).
  * "reference" — pure-jnp flash-style reference (the dry-run path).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _default_impl() -> str:
    try:
        plat = jax.default_backend()
    except Exception:  # pragma: no cover
        plat = "cpu"
    return "pallas" if plat == "tpu" else "reference"


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    chunk: Optional[int] = None,
    q_offset: int = 0,
    impl: str = "auto",
    q_block: int = 512,
    kv_block: int = 512,
) -> jnp.ndarray:
    if impl == "auto":
        impl = _default_impl()
    if impl in ("pallas", "interpret"):
        from repro.kernels.flash_attention import flash_attention_pallas

        return flash_attention_pallas(
            q,
            k,
            v,
            causal=causal,
            window=window,
            chunk=chunk,
            q_offset=q_offset,
            interpret=(impl == "interpret"),
        )
    return ref.flash_attention_jnp(
        q,
        k,
        v,
        causal=causal,
        window=window,
        chunk=chunk,
        q_block=q_block,
        kv_block=kv_block,
        q_offset=q_offset,
    )


def ssd(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    Bm: jnp.ndarray,
    Cm: jnp.ndarray,
    *,
    chunk: int = 256,
    initial_state: Optional[jnp.ndarray] = None,
    impl: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if impl == "auto":
        impl = _default_impl()
    if impl in ("pallas", "interpret"):
        from repro.kernels.ssd_scan import ssd_pallas

        return ssd_pallas(
            x, dt, A, Bm, Cm, chunk=chunk,
            initial_state=initial_state,
            interpret=(impl == "interpret"),
        )
    return ref.ssd_reference(x, dt, A, Bm, Cm, chunk=chunk, initial_state=initial_state)


def rmsnorm(
    x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5, *, impl: str = "auto"
) -> jnp.ndarray:
    if impl == "auto":
        impl = _default_impl()
    if impl in ("pallas", "interpret"):
        from repro.kernels.rmsnorm import rmsnorm_pallas

        return rmsnorm_pallas(x, w, eps, interpret=(impl == "interpret"))
    return ref.rmsnorm_reference(x, w, eps)
