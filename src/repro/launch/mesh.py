"""Production meshes (assignment MULTI-POD DRY-RUN §1).

A FUNCTION, not a module constant — importing this module never touches jax
device state.  Single pod: (16, 16) ("data", "model") = 256 chips.
Multi-pod: (2, 16, 16) ("pod", "data", "model") = 512 chips across 2 pods.
"""

from __future__ import annotations

import jax

# jax.sharding.AxisType landed after 0.4.x; Auto is the default there anyway
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def _make_mesh(shape, axes):
    if _AXIS_TYPE is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(
        shape, axes, axis_types=(_AXIS_TYPE.Auto,) * len(axes)
    )


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 4, *, multi_pod: bool = False):
    """Small mesh for CI-sized sharding tests (requires host-device override)."""
    if multi_pod:
        return _make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return _make_mesh((n_data, n_model), ("data", "model"))


def dp_total(mesh) -> int:
    n = mesh.shape.get("data", 1)
    if "pod" in mesh.shape:
        n *= mesh.shape["pod"]
    return n
