import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment MULTI-POD DRY-RUN).

For every (architecture × input shape × mesh) cell:
  * build the step function (train_step for train_4k, forward for
    prefill_32k, serve_step for decode_32k / long_500k),
  * ``jax.jit(...).lower(**input_specs)`` with explicit in/out shardings,
  * ``.compile()`` — success proves the sharding config is coherent,
  * print ``memory_analysis()`` (fits?) and ``cost_analysis()`` (FLOPs/bytes),
  * derive the three roofline terms (launch/roofline.py) and append the cell
    record to a JSON results file consumed by EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k --mesh single --out experiments/dryrun
"""

import argparse
import json
import pathlib
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_arch, shape_applicable
from repro.configs.registry import ARCHS
from repro.distributed.sharding import (
    logical_to_physical,
    mesh_context,
    spec_tree_to_shardings,
)
from repro.launch import roofline as RL
from repro.launch.mesh import dp_total, make_production_mesh
from repro.models import build_model
from repro.train.optimizer import AdamW, AdamWConfig
from repro.train.train_step import make_train_step


def _shardings(tree_specs, mesh, multi_pod):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, logical_to_physical(s, multi_pod)),
        tree_specs,
        is_leaf=lambda s: isinstance(s, tuple)
        and all(x is None or isinstance(x, (str, tuple)) for x in s),
    )


def _bf16_params(abstract):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        if s.dtype == jnp.float32 and len(s.shape) >= 2
        else s,
        abstract,
    )


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: pathlib.Path, tag: str = "baseline"):
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    mesh_name = "multi" if multi_pod else "single"
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "skipped" if not ok else "pending",
    }
    if not ok:
        rec["skip_reason"] = why
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256
    model = build_model(cfg, attn_impl="reference", remat=True)
    inputs, input_spec = model.input_specs(shape)

    with mesh_context(mesh, multi_pod):
        if shape.kind == "train":
            opt = AdamW(AdamWConfig(zero1=True))
            abstract_params = model.abstract_params()
            abstract_state = opt.abstract_state(abstract_params)
            p_shard = _shardings(model.param_specs(), mesh, multi_pod)
            s_shard = _shardings(
                opt.state_specs(model.param_defs(), dp_total(mesh)), mesh, multi_pod
            )
            b_shard = _shardings(input_spec, mesh, multi_pod)
            step = make_train_step(model, opt, microbatches=cfg.train_microbatches)
            repl = NamedSharding(mesh, P())
            out_shard = (
                p_shard,
                s_shard,
                {"loss": repl, "grad_norm": repl, "lr": repl},
            )
            jf = jax.jit(
                step,
                in_shardings=(p_shard, s_shard, b_shard),
                out_shardings=out_shard,
                donate_argnums=(0, 1),
            )
            args = (abstract_params, abstract_state, inputs)
            model_flops = RL.train_model_flops(
                model.n_active_params(), shape.global_batch * shape.seq_len
            )
        elif shape.kind == "prefill":
            abstract_params = _bf16_params(model.abstract_params())
            p_shard = _shardings(model.param_specs(), mesh, multi_pod)
            b_shard = _shardings(input_spec, mesh, multi_pod)
            jf = jax.jit(
                model.forward_step,
                in_shardings=(p_shard, b_shard),
            )
            args = (abstract_params, inputs)
            model_flops = (
                2.0 * model.n_active_params() * shape.global_batch * shape.seq_len
            )
        else:  # decode
            abstract_params = _bf16_params(model.abstract_params())
            p_shard = _shardings(model.param_specs(), mesh, multi_pod)
            c_shard = _shardings(input_spec["caches"], mesh, multi_pod)
            t_shard = _shardings(input_spec["token"], mesh, multi_pod)
            pos_shard = NamedSharding(mesh, P())

            def serve_step(params, caches, token, pos):
                return model.decode_step(params, caches, token, pos)

            jf = jax.jit(
                serve_step,
                in_shardings=(p_shard, c_shard, t_shard, pos_shard),
                out_shardings=(NamedSharding(mesh, P()), c_shard),
                donate_argnums=(1,),
            )
            args = (
                abstract_params,
                inputs["caches"],
                inputs["token"],
                inputs["pos"],
            )
            model_flops = RL.decode_model_flops(
                model.n_active_params(), shape.global_batch
            )

        lowered = jf.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # persist the optimized HLO so roofline re-analysis never needs a recompile
    import gzip

    hlo_path = out_dir / f"hlo__{tag}__{arch}__{shape_name}__{mesh_name}.txt.gz"
    with gzip.open(hlo_path, "wt") as fh:
        fh.write(compiled.as_text())

    mem = compiled.memory_analysis()
    print(f"[{arch} × {shape_name} × {mesh_name}] MEMORY:", mem)
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    print(
        f"[{arch} × {shape_name} × {mesh_name}] COST: flops={ca.get('flops', 0):.3e} "
        f"bytes={ca.get('bytes accessed', 0):.3e}"
    )
    rl = RL.roofline_from_compiled(compiled, model_flops=model_flops, n_chips=n_chips)

    per_dev_bytes = (
        getattr(mem, "argument_size_in_bytes", 0)
        + getattr(mem, "output_size_in_bytes", 0)
        - getattr(mem, "alias_size_in_bytes", 0)
        + getattr(mem, "temp_size_in_bytes", 0)
    )
    rec.update(
        status="ok",
        n_chips=n_chips,
        n_params=model.n_params(),
        n_active_params=model.n_active_params(),
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory={
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "per_device_total": per_dev_bytes,
            "fits_16G": bool(per_dev_bytes < 16e9),
        },
        roofline=rl.as_dict(),
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi_pod in meshes:
                mesh_name = "multi" if multi_pod else "single"
                path = out_dir / f"{args.tag}__{arch}__{shape}__{mesh_name}.json"
                if path.exists():
                    print(f"skip existing {path.name}")
                    continue
                try:
                    rec = run_cell(arch, shape, multi_pod, out_dir, tag=args.tag)
                except Exception as e:  # record failures, keep sweeping
                    failures += 1
                    rec = {
                        "arch": arch,
                        "shape": shape,
                        "mesh": mesh_name,
                        "status": "error",
                        "error": f"{type(e).__name__}: {e}",
                        "trace": traceback.format_exc()[-4000:],
                    }
                    print(f"[{arch} × {shape} × {mesh_name}] FAILED: {e}")
                path.write_text(json.dumps(rec, indent=2))
                print(f"wrote {path.name} status={rec['status']}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
