"""Roofline terms from compiled dry-run artifacts (assignment §ROOFLINE).

  compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
  memory term     = HLO_bytes / HBM_bw               (per chip)
  collective term = collective_bytes / link_bw       (per chip)

``compiled.cost_analysis()`` counts while-loop bodies ONCE, so a 62-layer
scanned model under-reports flops ~62×.  We therefore parse the optimized
HLO ourselves: build the computation graph, read each while op's
``known_trip_count`` from its backend_config, and accumulate

  * flops        — every ``dot`` op: 2 · |result| · |contraction dims|,
  * HBM traffic  — modeled for a WELL-FUSED TPU program: we count operand +
                   result bytes of data-movement ops (dot, conv, gather,
                   scatter, copy, transpose, dynamic-(update-)slice at slice
                   granularity, collectives, iota-free broadcasts excluded)
                   — NOT every fusion boundary.  The CPU-backend HLO leaves
                   flash-attention/softmax interiors as separate fusions
                   whose intermediates a TPU keeps in VMEM; counting those
                   (the first version of this parser did) inflates the
                   memory term ~50× and misranks every cell as
                   hopelessly memory-bound.  Dot results are still counted
                   (a ~2× pessimism for attention kernels whose scores stay
                   in VMEM) — the bias is conservative and uniform.
  * collectives  — operand bytes of all-gather / all-reduce /
                   reduce-scatter / all-to-all / collective-permute,

each × the product of enclosing loop trip counts.  Raw cost_analysis values
are recorded alongside for reference.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

# TPU v5e hardware constants (assignment)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# ops that MOVE data in a well-fused program (everything else is assumed
# fused into a neighbor's VMEM pipeline)
_TRAFFIC_OPS = {
    "dot", "convolution", "gather", "scatter", "copy", "transpose",
    "concatenate", "pad", "reduce", "sort",
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str):
        dtype, dims = m.group(1), m.group(2)
        b = _DTYPE_BYTES.get(dtype)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = re.match(r"[a-z0-9]+\[([0-9,]*)\]", shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(1).split(",") if d]


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_SHAPE_RE = re.compile(r"([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)")
_KIND_RE = re.compile(r"([\w\-]+)\(")


def _parse_op_line(line: str):
    """'%name = <shape|tuple> kind(args...' -> (name, shape, kind, args)."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rest = s[eq + 3 :]
    if rest.startswith("("):  # tuple type — balanced paren scan
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        shape = rest[: end + 1]
        rest2 = rest[end + 1 :].lstrip()
    else:
        m = _SHAPE_RE.match(rest)
        if not m:
            return None
        shape = m.group(1)
        rest2 = rest[m.end() :].lstrip()
    m2 = _KIND_RE.match(rest2)
    if not m2:
        return None
    return name, shape, m2.group(1), rest2[m2.end() :]


@dataclasses.dataclass
class HLOAnalysis:
    flops: float
    traffic_bytes: float
    collective_bytes: Dict[str, float]
    collective_counts: Dict[str, int]
    n_while: int

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze_hlo(hlo_text: str) -> HLOAnalysis:
    lines = hlo_text.splitlines()
    comps: Dict[str, List[Tuple[str, str, str, str]]] = {}  # name -> ops
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in lines:
        hdr = _COMP_HDR.match(line)
        if hdr:
            cur = hdr.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if cur is None or line.strip() in ("}", ""):
            continue
        parsed = _parse_op_line(line)
        if parsed:
            comps[cur].append(parsed)

    if entry is None:
        # fall back: last computation is usually the entry
        entry = list(comps)[-1] if comps else ""

    # per-computation symbol tables (op name -> result shape string)
    symtab: Dict[str, Dict[str, str]] = {
        c: {name: shape for name, shape, _, _ in ops} for c, ops in comps.items()
    }

    # while edges: (computation, body, cond, trip)
    while_edges: Dict[str, List[Tuple[str, str, int]]] = {c: [] for c in comps}
    n_while = 0
    for c, ops in comps.items():
        for name, shape, kind, rest in ops:
            if kind != "while":
                continue
            n_while += 1
            bm = re.search(r"body=%?([\w\.\-]+)", rest)
            cm = re.search(r"condition=%?([\w\.\-]+)", rest)
            tm = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', rest)
            trip = int(tm.group(1)) if tm else 1
            if bm:
                while_edges[c].append((bm.group(1), cm.group(1) if cm else "", trip))

    # multipliers: walk entry through while edges (+conditional branches ×1)
    mult: Dict[str, int] = {}

    def walk(c: str, m: int, depth: int = 0):
        if depth > 32 or c not in comps:
            return
        if mult.get(c, 0) >= m:
            return
        mult[c] = m
        for body, cond, trip in while_edges.get(c, []):
            walk(body, m * trip, depth + 1)
            walk(cond, m * trip, depth + 1)
        for name, shape, kind, rest in comps[c]:
            if kind == "conditional":
                for callee in re.findall(r"(?:branch_computations=\{|true_computation=|false_computation=)%?([\w\.\-,% ]+)", rest):
                    for cc in re.split(r"[,\s%]+", callee):
                        if cc:
                            walk(cc, m, depth + 1)

    walk(entry, 1)

    flops = 0.0
    traffic = 0.0
    coll_bytes: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    coll_counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}

    # FLOPs: dots wherever they appear; fusion computations inherit the
    # multiplier of the computation that calls them.
    fusion_mult: Dict[str, int] = {}
    for c, ops in comps.items():
        base = mult.get(c)
        if base is None:
            continue
        for name, shape, kind, rest in ops:
            for callee in re.findall(r"calls=%?([\w\.\-]+)", rest):
                fusion_mult[callee] = max(fusion_mult.get(callee, 0), base)
            for callee in re.findall(r"to_apply=%?([\w\.\-]+)", rest):
                fusion_mult[callee] = max(fusion_mult.get(callee, 0), base)

    def comp_mult(c: str) -> int:
        return mult.get(c, fusion_mult.get(c, 0))

    for c, ops in comps.items():
        m = comp_mult(c)
        if not m:
            continue
        st = symtab[c]
        in_real = c in mult  # collectives appear only in non-fusion comps
        for name, shape, kind, rest in ops:
            operand_str = rest.split(")")[0]
            opnames = re.findall(r"%([\w\.\-]+)", operand_str)
            if kind in ("dot", "convolution"):
                dims = _shape_dims(shape)
                out_elems = 1
                for d in dims:
                    out_elems *= d
                contr = 1
                lm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
                if lm and opnames:
                    lhs_shape = st.get(opnames[0], "")
                    ldims = _shape_dims(lhs_shape)
                    for idx in lm.group(1).split(","):
                        if idx and int(idx) < len(ldims):
                            contr *= ldims[int(idx)]
                flops += 2.0 * out_elems * contr * m
            if in_real:
                is_coll = None
                for ck in _COLLECTIVES:
                    if kind.startswith(ck):
                        is_coll = ck
                        break
                if is_coll:
                    ob = sum(_shape_bytes(st.get(o, "")) for o in opnames)
                    if ob == 0:
                        ob = _shape_bytes(shape)
                    coll_bytes[is_coll] += ob * m
                    coll_counts[is_coll] += 1
                    traffic += ob * m  # collectives also touch HBM
            # fused-TPU traffic model: only data-movement ops count.
            # dot/conv/gather/scatter/slice-updates count wherever they
            # appear; layout ops (copy/transpose/...) only at top level —
            # inside a fusion they are VMEM-resident.
            if kind == "dynamic-update-slice":
                upd = st.get(opnames[1], "") if len(opnames) > 1 else ""
                traffic += 2 * _shape_bytes(upd) * m
            elif kind == "dynamic-slice":
                traffic += 2 * _shape_bytes(shape) * m
            elif kind in ("dot", "convolution", "gather", "scatter"):
                ob = sum(_shape_bytes(st.get(o, "")) for o in opnames)
                traffic += (_shape_bytes(shape) + ob) * m
            elif in_real and kind in _TRAFFIC_OPS:
                ob = sum(_shape_bytes(st.get(o, "")) for o in opnames)
                traffic += (_shape_bytes(shape) + ob) * m

    return HLOAnalysis(
        flops=flops,
        traffic_bytes=traffic,
        collective_bytes=coll_bytes,
        collective_counts=coll_counts,
        n_while=n_while,
    )


@dataclasses.dataclass
class Roofline:
    flops: float               # per-chip HLO flops (trip-count corrected)
    hbm_bytes: float           # per-chip HLO bytes (trip-count corrected)
    collective_bytes: float    # per-chip collective operand bytes
    model_flops: float         # 6·N·D (train) / 2·N·B (decode), N_active
    n_chips: int
    raw_cost_flops: float = 0.0
    raw_cost_bytes: float = 0.0
    collective_detail: Optional[Dict[str, float]] = None
    collective_counts: Optional[Dict[str, int]] = None

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        ts = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(ts, key=ts.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achieved fraction of the compute roofline: time to do the USEFUL
        flops at peak vs. the dominant-term time of the compiled program."""
        t_useful = (self.model_flops / self.n_chips) / PEAK_FLOPS
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / t_bound if t_bound else 0.0

    def as_dict(self) -> Dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "collective_bytes_per_chip": self.collective_bytes,
            "raw_cost_flops": self.raw_cost_flops,
            "raw_cost_bytes": self.raw_cost_bytes,
            "model_flops": self.model_flops,
            "n_chips": self.n_chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collective_bytes_by_type": self.collective_detail,
            "collective_count_by_type": self.collective_counts,
        }


def roofline_from_compiled(compiled, *, model_flops: float, n_chips: int) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    an = analyze_hlo(compiled.as_text())
    return Roofline(
        flops=an.flops,
        hbm_bytes=an.traffic_bytes,
        collective_bytes=an.total_collective_bytes,
        model_flops=model_flops,
        n_chips=n_chips,
        raw_cost_flops=float(ca.get("flops", 0.0)),
        raw_cost_bytes=float(ca.get("bytes accessed", 0.0)),
        collective_detail=an.collective_bytes,
        collective_counts=an.collective_counts,
    )


def analyze_jitted(fn, *args) -> HLOAnalysis:
    """Trip-count-corrected HLO analysis of ``jax.jit(fn)`` on ``args``.

    jax is imported lazily — this module stays importable (and every other
    entry point usable) on hosts without jax.
    """
    import jax

    compiled = jax.jit(fn).lower(*args).compile()
    return analyze_hlo(compiled.as_text())


def kernel_roofline(fn, *args) -> Roofline:
    """Single-chip roofline for one jitted kernel (data-plane reporting).

    The fused-program traffic model (module docstring) counts only interior
    data-movement ops, which reports **zero** bytes for a pure elementwise
    kernel — but a standalone kernel must still stream its operands and
    results through HBM, so entry I/O bytes are applied as a floor.

    ``model_flops`` is set to the HLO dot-flops — relational kernels have
    no model-level flop count of their own, so ``useful_flops_ratio`` is
    1.0 by construction and only the time terms / bottleneck matter.
    """
    import math

    import jax

    an = analyze_jitted(fn, *args)
    leaves = list(args) + list(jax.tree_util.tree_leaves(jax.eval_shape(fn, *args)))
    io_bytes = sum(
        math.prod(x.shape) * x.dtype.itemsize for x in leaves
    )
    return Roofline(
        flops=an.flops,
        hbm_bytes=max(an.traffic_bytes, float(io_bytes)),
        collective_bytes=an.total_collective_bytes,
        model_flops=an.flops,
        n_chips=1,
        collective_detail=an.collective_bytes,
        collective_counts=an.collective_counts,
    )


def is_bandwidth_bound(fn, *args) -> bool:
    """True when the memory term dominates the compute term for ``fn``.

    Used by ``kernels/relational.py`` to gate the Pallas lowering of the
    fused filter/project kernels: elementwise relational bodies carry zero
    dot-flops, so they are bandwidth-bound whenever they move any bytes.
    """
    r = kernel_roofline(fn, *args)
    return r.t_memory >= r.t_compute


def train_model_flops(n_active_params: float, tokens: float) -> float:
    return 6.0 * n_active_params * tokens


def decode_model_flops(n_active_params: float, batch: float) -> float:
    return 2.0 * n_active_params * batch
