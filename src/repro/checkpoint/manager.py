"""Fault-tolerant, mesh-elastic checkpointing.

Design for 1000+ nodes (DESIGN.md §5):
  * **Atomic**: writes go to ``step_XXXX.tmp/`` and are renamed into place —
    a crash mid-write can never corrupt the latest checkpoint.
  * **Async**: the device→host gather happens synchronously (cheap), the
    disk write happens on a writer thread so the train loop keeps stepping.
  * **Mesh-elastic**: arrays are stored as *logical* (unsharded) tensors
    with the logical PartitionSpec alongside; restore re-shards onto
    whatever mesh the restarted job has (elastic scaling — a 512-chip
    checkpoint restores onto 256 chips and vice versa).
  * **Content-hash dedup** (paper Use case 2): each array file is named by
    its content hash inside a shared object store; checkpoints reference
    objects, so consecutive checkpoints share unchanged tensors (e.g. the
    data-pipeline materializations or frozen embeddings) and Veer-verified
    equivalent pipeline versions share materialized results.
"""

from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import tempfile
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _tree_flatten_with_names(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_write: bool = True):
        self.dir = pathlib.Path(directory)
        self.objects = self.dir / "objects"
        self.dir.mkdir(parents=True, exist_ok=True)
        self.objects.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._writer: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    # -- save ------------------------------------------------------------------
    def save(self, step: int, state: Any, *, metadata: Optional[Dict] = None) -> None:
        # gather to host synchronously (consistent snapshot)
        host = [
            (name, np.asarray(jax.device_get(leaf)))
            for name, leaf in _tree_flatten_with_names(state)
        ]
        treedef = jax.tree_util.tree_structure(state)
        meta = dict(metadata or {})
        meta["step"] = step
        meta["treedef"] = str(treedef)

        def write():
            with self._lock:
                self._write_snapshot(step, host, meta)

        self.wait()
        if self.async_write:
            self._writer = threading.Thread(target=write, daemon=True)
            self._writer.start()
        else:
            write()

    def _write_snapshot(self, step, host, meta):
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        index = {}
        for name, arr in host:
            digest = hashlib.sha256(arr.tobytes() + str(arr.dtype).encode() + str(arr.shape).encode()).hexdigest()[:32]
            obj = self.objects / f"{digest}.npy"
            if not obj.exists():  # dedup: shared unchanged tensors
                fd, tmpname = tempfile.mkstemp(dir=self.objects)
                os.close(fd)
                np.save(tmpname, arr, allow_pickle=False)
                os.replace(tmpname + ".npy" if os.path.exists(tmpname + ".npy") else tmpname, obj)
            index[name] = {
                "object": digest,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
        (tmp / "index.json").write_text(json.dumps(index))
        (tmp / "meta.json").write_text(json.dumps(meta))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
        # object GC: drop unreferenced objects
        referenced = set()
        for s in self.all_steps():
            idx = self.dir / f"step_{s:08d}" / "index.json"
            if idx.exists():
                for rec in json.loads(idx.read_text()).values():
                    referenced.add(rec["object"])
        for obj in self.objects.glob("*.npy"):
            if obj.stem not in referenced:
                obj.unlink(missing_ok=True)

    def wait(self):
        if self._writer is not None:
            self._writer.join()
            self._writer = None

    # -- restore ------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "index.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: Optional[int],
        like: Any,
        *,
        shardings: Any = None,
    ) -> Tuple[Any, Dict]:
        """Restore into the structure of ``like`` (re-sharding onto the
        current mesh when ``shardings`` is given — elastic restart)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        snap = self.dir / f"step_{step:08d}"
        index = json.loads((snap / "index.json").read_text())
        meta = json.loads((snap / "meta.json").read_text())
        names = [n for n, _ in _tree_flatten_with_names(like)]
        flat_like, treedef = jax.tree_util.tree_flatten(like)
        shard_flat = (
            jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(flat_like)
        )
        leaves = []
        for name, ref_leaf, shd in zip(names, flat_like, shard_flat):
            rec = index[name]
            arr = np.load(self.objects / f"{rec['object']}.npy")
            if shd is not None:
                leaves.append(jax.device_put(arr, shd))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves), meta
